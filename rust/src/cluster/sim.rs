//! Per-device timeline simulation of a lowered SPMD program.
//!
//! SPMD: all devices execute the same schedule, so the step time is one
//! device's serial timeline with collectives priced by the interconnect
//! model (XLA does not overlap compute and collectives by default, and the
//! paper explicitly scopes overlap out — §7.2).

use std::collections::BTreeMap;

use crate::spmd::{CollKind, Instr, SpmdProgram};

use super::collective::{achieved_bandwidth_gbps, collective_time_us};
use super::platform::Platform;

/// Compute-kernel efficiency curve: fraction of peak as a function of
/// kernel size. Calibrated from real PJRT kernel measurements by the
/// profiler (`runtime::calibrate`); this default is the uncalibrated
/// analytic shape.
#[derive(Clone, Debug)]
pub struct ComputeModel {
    pub peak_tflops: f64,
    pub hbm_gbps: f64,
    pub launch_us: f64,
    /// flops at which half of max efficiency is reached
    pub sat_flops: f64,
    /// max achievable fraction of peak (calibration scales this)
    pub max_eff: f64,
}

impl ComputeModel {
    pub fn for_platform(p: &Platform) -> ComputeModel {
        ComputeModel {
            peak_tflops: p.peak_tflops,
            hbm_gbps: p.hbm_gbps,
            launch_us: p.kernel_launch_us,
            sat_flops: 5.0e8 / p.time_scale,
            max_eff: 0.62,
        }
    }

    /// Canonical encoding for the profile-cache key: a recalibrated
    /// compute model (different `sat_flops`) must invalidate cached
    /// kernel-time profiles.
    pub fn signature(&self) -> String {
        format!(
            "cm:tf{}hbm{}l{}sat{}me{}",
            self.peak_tflops, self.hbm_gbps, self.launch_us, self.sat_flops, self.max_eff
        )
    }

    pub fn efficiency(&self, flops: u64) -> f64 {
        let f = flops as f64;
        (self.max_eff * f / (f + self.sat_flops)).max(0.02)
    }

    pub fn time_us(&self, flops: u64, bytes: u64) -> f64 {
        if flops == 0 && bytes == 0 {
            return 0.0;
        }
        let eff = self.efficiency(flops);
        let t_flops = flops as f64 / (self.peak_tflops * eff * 1e6); // µs
        let t_mem = bytes as f64 / (self.hbm_gbps * 1e3);
        self.launch_us + t_flops.max(t_mem)
    }
}

/// Simulation result for one training step.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub total_us: f64,
    pub compute_us: f64,
    pub comm_us: f64,
    pub comm_inter_us: f64,
    /// per collective kind: (kernel count, total bytes, total µs)
    pub comm_by_kind: BTreeMap<&'static str, (usize, u64, f64)>,
    pub comm_volume: u64,
    pub comm_kernels: usize,
    /// volume-weighted achieved bandwidth, GB/s (Fig. 8's busbw metric)
    pub achieved_bw_gbps: f64,
}

impl SimReport {
    pub fn throughput_flops(&self, serial_flops: u64) -> f64 {
        if self.total_us <= 0.0 {
            return 0.0;
        }
        serial_flops as f64 / (self.total_us * 1e-6)
    }
}

pub fn kind_name(k: CollKind) -> &'static str {
    match k {
        CollKind::AllReduce => "all-reduce",
        CollKind::AllGather => "all-gather",
        CollKind::ReduceScatter => "reduce-scatter",
        CollKind::AllToAll => "all-to-all",
        CollKind::Broadcast => "broadcast",
        CollKind::SendRecv => "send-recv",
    }
}

/// Simulate a program on `platform`, with `intra_n` devices in the
/// intra-op group (≤ gpus_per_node) and the platform's node count on the
/// inter axis.
pub fn simulate(
    prog: &SpmdProgram,
    platform: &Platform,
    intra_n: usize,
    cm: &ComputeModel,
) -> SimReport {
    let mut r = SimReport::default();
    let mut wire_sum = 0.0f64;
    let mut time_sum = 0.0f64;
    for instr in &prog.instrs {
        match instr {
            Instr::Compute { flops, bytes, .. } => {
                r.compute_us += cm.time_us(*flops, *bytes);
            }
            Instr::Coll { kind, bytes, .. } => {
                let t = collective_time_us(*kind, *bytes, intra_n, &platform.intra);
                r.comm_us += t;
                let e = r.comm_by_kind.entry(kind_name(*kind)).or_insert((0, 0, 0.0));
                e.0 += 1;
                e.1 += bytes;
                e.2 += t;
                r.comm_volume += bytes;
                r.comm_kernels += 1;
                let bw = achieved_bandwidth_gbps(*kind, *bytes, intra_n, t);
                wire_sum += bw * t;
                time_sum += t;
            }
            Instr::CollInter { kind, bytes, .. } => {
                let t = collective_time_us(*kind, *bytes, platform.nodes, &platform.inter);
                r.comm_inter_us += t;
                let e = r.comm_by_kind.entry("inter-node").or_insert((0, 0, 0.0));
                e.0 += 1;
                e.1 += bytes;
                e.2 += t;
                r.comm_volume += bytes;
                r.comm_kernels += 1;
                let bw = achieved_bandwidth_gbps(*kind, *bytes, platform.nodes, t);
                wire_sum += bw * t;
                time_sum += t;
            }
        }
    }
    r.total_us = r.compute_us + r.comm_us + r.comm_inter_us;
    r.achieved_bw_gbps = if time_sum > 0.0 { wire_sum / time_sum } else { 0.0 };
    r
}

/// Composed inter-op pipeline schedule (the two-level planner's outer
/// level): `microbatches` identical jobs flow through `k` stages in order,
/// stage `i` taking `latencies_us[i]` per microbatch (intra-op stage time
/// plus incoming point-to-point transfer).
#[derive(Clone, Debug, Default)]
pub struct PipelineSchedule {
    /// end-to-end step time (last stage finishes the last microbatch)
    pub makespan_us: f64,
    /// per-stage busy time (`latency · microbatches`)
    pub stage_busy_us: Vec<f64>,
    /// 1 − busiest-stage share of the makespan (the pipeline bubble)
    pub bubble_fraction: f64,
}

/// Event-driven simulation of the composed pipeline schedule: stage `i`
/// starts microbatch `j` once stage `i−1` delivered `j` AND stage `i`
/// finished `j−1` (synchronous 1F1B-style flow line, unlimited buffers).
/// For identical microbatches this makespan equals the closed form
/// `Σᵢ lᵢ + (m−1)·maxᵢ lᵢ` — the inter-op DP optimizes exactly that
/// quantity, and `interop` tests pin the two to each other.
pub fn simulate_pipeline(latencies_us: &[f64], microbatches: usize) -> PipelineSchedule {
    let m = microbatches.max(1);
    // finish[j]: time the previous stage delivered microbatch j
    let mut finish = vec![0.0f64; m];
    for &l in latencies_us {
        let mut prev_done = 0.0f64;
        for f in finish.iter_mut() {
            let start = if *f > prev_done { *f } else { prev_done };
            prev_done = start + l;
            *f = prev_done;
        }
    }
    let makespan_us = finish.last().copied().unwrap_or(0.0);
    let stage_busy_us: Vec<f64> = latencies_us.iter().map(|&l| l * m as f64).collect();
    let busiest = stage_busy_us.iter().cloned().fold(0.0f64, f64::max);
    let bubble_fraction =
        if makespan_us > 0.0 { (1.0 - busiest / makespan_us).max(0.0) } else { 0.0 };
    PipelineSchedule { makespan_us, stage_busy_us, bubble_fraction }
}

/// Per-stage memory parameters of the 1F1B memory simulation — the
/// per-microbatch shares the caller derives from a whole-batch
/// [`crate::memory::SpanFootprint`] (same floor division as the closed
/// form, so sim and formula agree bit-for-bit).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageMemSpec {
    /// weights + gradient buckets + optimizer state
    pub static_bytes: u64,
    /// activation bytes one microbatch retains until its backward
    pub retained_per_mb: u64,
    /// recompute scratch live while one microbatch's backward runs
    pub transient_per_mb: u64,
}

/// Event-driven 1F1B schedule with live-memory tracking: every stage runs
/// the canonical one-forward-one-backward order (stage `i` of `k` does
/// `min(m, k − i)` warmup forwards, then alternates backward/forward,
/// then drains), with forwards gated on the upstream stage's delivery and
/// backwards on the downstream stage's gradient. Activations are counted
/// in when a forward executes and out when the microbatch's backward
/// completes; recompute scratch is live during the backward. Returns each
/// stage's high-water mark — the quantity
/// [`crate::memory::stage_peak_bytes`] predicts in closed form
/// (`static + min(m, k − i) · retained + transient`); the
/// `integration_memory` tests pin the two to each other exactly.
///
/// Panics if the dependency graph cannot make progress (an invalid
/// schedule — impossible for the canonical 1F1B window).
pub fn simulate_pipeline_memory(
    latencies_us: &[f64],
    microbatches: usize,
    mem: &[StageMemSpec],
) -> Vec<u64> {
    let k = latencies_us.len();
    assert_eq!(mem.len(), k, "one memory spec per stage");
    if k == 0 {
        return Vec::new();
    }
    let m = microbatches.max(1);

    // canonical 1F1B task order per stage: (is_backward, microbatch)
    let mut seq: Vec<Vec<(bool, usize)>> = Vec::with_capacity(k);
    for i in 0..k {
        let w = (k - i).min(m);
        let mut s = Vec::with_capacity(2 * m);
        for j in 0..w {
            s.push((false, j));
        }
        let mut next_f = w;
        for j in 0..m {
            s.push((true, j));
            if next_f < m {
                s.push((false, next_f));
                next_f += 1;
            }
        }
        seq.push(s);
    }

    // timed execution honoring cross-stage dependencies; the half/half
    // forward/backward split shapes only the timeline, not the counting
    let mut fwd_done: Vec<Vec<Option<f64>>> = vec![vec![None; m]; k];
    let mut bwd_done: Vec<Vec<Option<f64>>> = vec![vec![None; m]; k];
    let mut pos = vec![0usize; k];
    let mut stage_free = vec![0.0f64; k];
    let mut retained = vec![0usize; k];
    let mut high: Vec<u64> = mem.iter().map(|s| s.static_bytes).collect();
    let total: usize = seq.iter().map(|s| s.len()).sum();
    let mut done = 0usize;
    while done < total {
        let mut progressed = false;
        for i in 0..k {
            while pos[i] < seq[i].len() {
                let (is_bwd, j) = seq[i][pos[i]];
                let dep = if is_bwd {
                    match (fwd_done[i][j], if i + 1 < k { bwd_done[i + 1][j] } else { Some(0.0) })
                    {
                        (Some(a), Some(b)) => Some(a.max(b)),
                        _ => None,
                    }
                } else if i > 0 {
                    fwd_done[i - 1][j]
                } else {
                    Some(0.0)
                };
                let Some(dep) = dep else { break };
                let start = stage_free[i].max(dep);
                let end = start + latencies_us[i].max(0.0) / 2.0;
                if is_bwd {
                    let live = mem[i].static_bytes
                        + retained[i] as u64 * mem[i].retained_per_mb
                        + mem[i].transient_per_mb;
                    high[i] = high[i].max(live);
                    retained[i] -= 1;
                    bwd_done[i][j] = Some(end);
                } else {
                    retained[i] += 1;
                    let live =
                        mem[i].static_bytes + retained[i] as u64 * mem[i].retained_per_mb;
                    high[i] = high[i].max(live);
                    fwd_done[i][j] = Some(end);
                }
                stage_free[i] = end;
                pos[i] += 1;
                done += 1;
                progressed = true;
            }
        }
        assert!(progressed, "1F1B schedule deadlocked — invalid dependency window");
    }
    high
}

/// One node of a series-parallel segment-DAG execution
/// ([`crate::spdag::sim_tasks`] builds these from a fixed plan). `deps`
/// carry the reshard cost of each incoming edge. Three node shapes:
///
/// * plain chain step (`seed_zero = false`, `rebase = None`, ≤ 1 dep):
///   `fin = (fin_pred + reshard) + time`;
/// * branch head (`seed_zero = true`): the branch runs on a local clock —
///   `fin = (0.0 + fork_reshard) + time` — while the dep still gates when
///   the node may fire;
/// * merge-owning successor (`rebase = Some(fork)`): branches complete
///   concurrently, so `fin = (fin_fork + max_d(fin_d + reshard_d)) +
///   time`, the max folded over `deps` in listed order with first-wins
///   ties — the planner's own association, reproduced bit-for-bit.
#[derive(Clone, Debug)]
pub struct SpTask {
    /// node compute time (the segment's `t_c + t_p`), µs
    pub time_us: f64,
    /// incoming edges as `(task index, reshard µs)`
    pub deps: Vec<(usize, f64)>,
    /// branch head: fold from the branch-local zero clock
    pub seed_zero: bool,
    /// merge: rebase the folded branch max onto this (fork) task's clock
    pub rebase: Option<usize>,
}

/// Event-driven execution of a series-parallel segment-DAG task list:
/// a genuine dependency-counting worklist (lowest-index-ready order, so
/// runs are deterministic), with each node's completion computed by the
/// fold documented on [`SpTask`]. For task lists built by
/// [`crate::spdag::sim_tasks`] the returned finish times equal the
/// SP-DAG planner's closed-form span times **bit-for-bit** — the same
/// invariant `simulate_pipeline` keeps with the inter-op DP.
///
/// Panics on a malformed list (forward or self dependencies, a
/// multi-dep node that is not a merge).
pub fn simulate_sp_dag(tasks: &[SpTask]) -> Vec<f64> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let n = tasks.len();
    let mut indeg = vec![0usize; n];
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, t) in tasks.iter().enumerate() {
        assert!(
            t.rebase.is_some() || t.deps.len() <= 1,
            "task {i}: only merge nodes may have multiple dependencies"
        );
        let mut preds: Vec<usize> = t.deps.iter().map(|&(p, _)| p).collect();
        preds.extend(t.rebase);
        preds.sort_unstable();
        preds.dedup();
        for p in preds {
            assert!(p < i, "task {i}: dependency {p} must point backwards");
            indeg[i] += 1;
            out[p].push(i);
        }
    }

    let mut fin = vec![0.0f64; n];
    let mut ready: BinaryHeap<Reverse<usize>> =
        (0..n).filter(|&i| indeg[i] == 0).map(Reverse).collect();
    let mut fired = 0usize;
    while let Some(Reverse(i)) = ready.pop() {
        let t = &tasks[i];
        fin[i] = if let Some(f) = t.rebase {
            let mut mx = f64::NEG_INFINITY;
            for &(p, r) in &t.deps {
                let w = fin[p] + r;
                if w > mx {
                    mx = w;
                }
            }
            (fin[f] + mx) + t.time_us
        } else if let Some(&(p, r)) = t.deps.first() {
            let base = if t.seed_zero { 0.0 } else { fin[p] };
            (base + r) + t.time_us
        } else {
            t.time_us
        };
        fired += 1;
        for &s in &out[i] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push(Reverse(s));
            }
        }
    }
    assert_eq!(fired, n, "dependency cycle in SP-DAG task list");
    fin
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::spmd::{lower, passes, GlobalPlan, Mesh};

    fn sim_plan(label: &str, bucket: bool) -> SimReport {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(2).with_batch(8);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let plan = GlobalPlan::uniform(&bs, label, Mesh::flat(4)).unwrap();
        let mut prog = lower(&g, &bs, &plan);
        if bucket {
            passes::bucket_gradients(&mut prog, 25 << 20);
        }
        let p = Platform::a100_pcie(4);
        simulate(&prog, &p, 4, &ComputeModel::for_platform(&p))
    }

    #[test]
    fn bucketing_cuts_dp_comm_time() {
        let unbucketed = sim_plan("m", false);
        let bucketed = sim_plan("m", true);
        assert_eq!(unbucketed.comm_volume, bucketed.comm_volume, "volume invariant");
        assert!(
            bucketed.comm_us < 0.8 * unbucketed.comm_us,
            "bucketing speeds comm: {} vs {}",
            bucketed.comm_us,
            unbucketed.comm_us
        );
    }

    #[test]
    fn compute_model_monotone() {
        let p = Platform::a100_pcie(4);
        let cm = ComputeModel::for_platform(&p);
        assert!(cm.time_us(1 << 20, 1 << 10) < cm.time_us(1 << 30, 1 << 10));
        // big kernels run near max efficiency
        assert!(cm.efficiency(u64::MAX / 2) > 0.6 * cm.max_eff);
        // tiny kernels are launch-bound
        assert!(cm.time_us(100, 100) < 2.0 * cm.launch_us);
    }

    #[test]
    fn report_totals_consistent() {
        let r = sim_plan("m", true);
        assert!(r.total_us > 0.0);
        assert!((r.total_us - r.compute_us - r.comm_us - r.comm_inter_us).abs() < 1e-6);
        let kind_total: f64 = r.comm_by_kind.values().map(|(_, _, t)| t).sum();
        assert!((kind_total - r.comm_us - r.comm_inter_us).abs() < 1e-6);
    }

    #[test]
    fn pipeline_schedule_matches_closed_form() {
        for (lats, m) in [
            (vec![10.0], 1usize),
            (vec![10.0], 8),
            (vec![5.0, 5.0, 5.0], 4),
            (vec![3.0, 9.0, 6.0, 1.0], 6),
        ] {
            let sim = simulate_pipeline(&lats, m);
            let sum: f64 = lats.iter().sum();
            let mx = lats.iter().cloned().fold(0.0f64, f64::max);
            let closed = sum + (m as f64 - 1.0) * mx;
            assert!(
                (sim.makespan_us - closed).abs() < 1e-6 * closed.max(1.0),
                "{lats:?} m={m}: sim {} vs closed {closed}",
                sim.makespan_us
            );
        }
    }

    #[test]
    fn single_stage_pipeline_is_serial() {
        let sim = simulate_pipeline(&[7.25], 8);
        assert!((sim.makespan_us - 8.0 * 7.25).abs() < 1e-9);
        assert!(sim.bubble_fraction.abs() < 1e-12, "no bubble with one stage");
    }

    #[test]
    fn unbalanced_stages_grow_the_bubble() {
        let balanced = simulate_pipeline(&[5.0, 5.0], 8);
        let skewed = simulate_pipeline(&[2.0, 8.0], 8);
        assert!(skewed.makespan_us > balanced.makespan_us);
        assert!(skewed.bubble_fraction > balanced.bubble_fraction);
    }

    #[test]
    fn pipeline_memory_high_water_matches_1f1b_window() {
        // 4 stages, 8 microbatches: stage i holds min(8, 4 − i) sets
        let lats = [10.0, 12.0, 8.0, 11.0];
        let spec = StageMemSpec { static_bytes: 1000, retained_per_mb: 100, transient_per_mb: 7 };
        let high = simulate_pipeline_memory(&lats, 8, &[spec; 4]);
        for (i, h) in high.iter().enumerate() {
            let f = (4 - i).min(8) as u64;
            assert_eq!(*h, 1000 + f * 100 + 7, "stage {i}");
        }
    }

    #[test]
    fn pipeline_memory_microbatch_count_caps_the_window() {
        let spec = StageMemSpec { static_bytes: 0, retained_per_mb: 10, transient_per_mb: 0 };
        let high = simulate_pipeline_memory(&[5.0, 5.0, 5.0, 5.0], 2, &[spec; 4]);
        assert_eq!(high, vec![20, 20, 20, 10], "windows min(2, 4−i)");
    }

    #[test]
    fn single_stage_memory_is_whole_batch() {
        let spec = StageMemSpec { static_bytes: 5, retained_per_mb: 3, transient_per_mb: 2 };
        assert_eq!(simulate_pipeline_memory(&[7.0], 1, &[spec]), vec![10]);
    }

    #[test]
    fn memory_high_water_is_schedule_shape_not_timing() {
        let spec = StageMemSpec { static_bytes: 0, retained_per_mb: 1, transient_per_mb: 0 };
        let a = simulate_pipeline_memory(&[1.0, 100.0, 1.0], 6, &[spec; 3]);
        let b = simulate_pipeline_memory(&[100.0, 1.0, 100.0], 6, &[spec; 3]);
        assert_eq!(a, b, "canonical 1F1B pins the window regardless of stage balance");
    }

    #[test]
    fn sp_dag_sim_reproduces_the_branch_merge_fold_bitwise() {
        // fork(2.0) → two 0.0-seeded branches → rebased merge → trunk
        let tasks = vec![
            SpTask { time_us: 2.0, deps: vec![], seed_zero: false, rebase: None },
            SpTask { time_us: 3.0, deps: vec![(0, 0.5)], seed_zero: true, rebase: None },
            SpTask { time_us: 1.0, deps: vec![(0, 0.25)], seed_zero: true, rebase: None },
            SpTask {
                time_us: 4.0,
                deps: vec![(1, 1.0), (2, 2.0)],
                seed_zero: false,
                rebase: Some(0),
            },
            SpTask { time_us: 1.5, deps: vec![(3, 0.125)], seed_zero: false, rebase: None },
        ];
        let fin = simulate_sp_dag(&tasks);
        // branch-local clocks: (0.0 + 0.5) + 3.0 = 3.5 and (0.0 + 0.25) + 1.0 = 1.25
        assert_eq!(fin[1].to_bits(), 3.5f64.to_bits());
        assert_eq!(fin[2].to_bits(), 1.25f64.to_bits());
        // merge: (2.0 + max(3.5 + 1.0, 1.25 + 2.0)) + 4.0
        assert_eq!(fin[3].to_bits(), ((2.0 + (3.5 + 1.0)) + 4.0).to_bits());
        assert_eq!(fin[4].to_bits(), ((fin[3] + 0.125) + 1.5).to_bits());
    }

    #[test]
    fn sp_dag_sim_chain_degenerates_to_the_left_fold() {
        let tasks = vec![
            SpTask { time_us: 4.0, deps: vec![], seed_zero: false, rebase: None },
            SpTask { time_us: 5.0, deps: vec![(0, 0.5)], seed_zero: false, rebase: None },
            SpTask { time_us: 6.0, deps: vec![(1, 0.25)], seed_zero: false, rebase: None },
        ];
        let fin = simulate_sp_dag(&tasks);
        assert_eq!(fin[2].to_bits(), ((((4.0f64 + 0.5) + 5.0) + 0.25) + 6.0).to_bits());
    }

    #[test]
    fn sp_dag_sim_merge_ties_are_first_wins() {
        // both branches complete at exactly 3.0; the fold must keep the
        // first operand's bits (strict > comparison)
        let tasks = vec![
            SpTask { time_us: 1.0, deps: vec![], seed_zero: false, rebase: None },
            SpTask { time_us: 3.0, deps: vec![(0, 0.0)], seed_zero: true, rebase: None },
            SpTask { time_us: 2.0, deps: vec![(0, 1.0)], seed_zero: true, rebase: None },
            SpTask {
                time_us: 0.5,
                deps: vec![(1, 0.0), (2, 0.0)],
                seed_zero: false,
                rebase: Some(0),
            },
        ];
        let fin = simulate_sp_dag(&tasks);
        assert_eq!(fin[3].to_bits(), ((1.0 + 3.0f64) + 0.5).to_bits());
    }

    #[test]
    fn nvlink_shrinks_comm_share() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(2).with_batch(8);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let plan = GlobalPlan::uniform(&bs, "k", Mesh::flat(4)).unwrap();
        let prog = lower(&g, &bs, &plan);
        let pcie = Platform::a100_pcie(4);
        let nv = Platform::v100_nvlink();
        let r_p = simulate(&prog, &pcie, 4, &ComputeModel::for_platform(&pcie));
        let r_n = simulate(&prog, &nv, 4, &ComputeModel::for_platform(&nv));
        assert!(
            r_n.comm_us / r_n.total_us < r_p.comm_us / r_p.total_us,
            "nvlink comm share {} < pcie {}",
            r_n.comm_us / r_n.total_us,
            r_p.comm_us / r_p.total_us
        );
    }
}
