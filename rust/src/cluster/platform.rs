//! Platform definitions: interconnects + device compute capability.

/// Link model: effective bandwidth saturates with message size
/// (`eff_bw(msg) = peak · msg / (msg + sat)`), plus per-kernel launch cost
/// and per-algorithm-step latency.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// peak bus bandwidth per direction, GB/s
    pub peak_gbps: f64,
    /// message size (bytes) at which half of peak is reached
    pub sat_bytes: f64,
    /// per-collective-kernel launch overhead, µs
    pub launch_us: f64,
    /// per-ring-step latency, µs
    pub step_us: f64,
    /// multiplier on SendRecv pairwise transfers (PCIe penalizes them)
    pub sendrecv_penalty: f64,
}

impl LinkModel {
    pub fn eff_bw_gbps(&self, msg_bytes: f64) -> f64 {
        self.peak_gbps * msg_bytes / (msg_bytes + self.sat_bytes)
    }

    /// Canonical encoding of every cost-affecting field — part of the
    /// profile-cache key, so any link-model change invalidates cached
    /// profiles (`{}` on f64 prints the shortest round-trippable form).
    pub fn signature(&self) -> String {
        format!(
            "bw{}s{}l{}st{}sr{}",
            self.peak_gbps, self.sat_bytes, self.launch_us, self.step_us, self.sendrecv_penalty
        )
    }
}

/// A training platform (the paper's testbeds, simulated).
#[derive(Clone, Copy, Debug)]
pub struct Platform {
    pub name: &'static str,
    /// devices per node participating in intra-op parallelism
    pub gpus_per_node: usize,
    pub nodes: usize,
    pub intra: LinkModel,
    /// inter-node link (multi-node platforms)
    pub inter: LinkModel,
    /// peak dense-matmul throughput per device, TFLOP/s
    pub peak_tflops: f64,
    /// HBM bandwidth, GB/s (memory-bound kernel roofline)
    pub hbm_gbps: f64,
    /// per-compute-kernel launch overhead, µs
    pub kernel_launch_us: f64,
    /// time-scale divisor applied by [`Platform::scaled_testbed`] (1.0 for
    /// the full-scale platform); consumed by ComputeModel::for_platform
    pub time_scale: f64,
}

impl Platform {
    /// 4/8× NVIDIA A100-40GB over PCIe 4.0 (≈24 GB/s effective per pair,
    /// shared host bus ⇒ low saturation, expensive send/recv).
    pub fn a100_pcie(gpus: usize) -> Platform {
        Platform {
            name: "a100-pcie",
            gpus_per_node: gpus,
            nodes: 1,
            intra: LinkModel {
                peak_gbps: 22.0,
                sat_bytes: 4.0e6,
                launch_us: 14.0,
                step_us: 6.0,
                sendrecv_penalty: 6.0,
            },
            inter: ethernet(),
            peak_tflops: 140.0, // TF32 with sparsity off
            hbm_gbps: 1555.0,
            kernel_launch_us: 4.5,
            time_scale: 1.0,
        }
    }

    /// Two A100-PCIe nodes with 100 Gb Ethernet between them (16 GPUs).
    pub fn a100_two_node() -> Platform {
        Platform {
            name: "a100-2node",
            nodes: 2,
            gpus_per_node: 8,
            ..Platform::a100_pcie(8)
        }
    }

    /// 4× V100-16GB with NVLink (≈120 GB/s effective, cheap steps).
    pub fn v100_nvlink() -> Platform {
        Platform {
            name: "v100-nvlink",
            gpus_per_node: 4,
            nodes: 1,
            intra: LinkModel {
                peak_gbps: 120.0,
                sat_bytes: 1.0e6,
                launch_us: 9.0,
                step_us: 2.5,
                sendrecv_penalty: 1.2,
            },
            inter: ethernet(),
            peak_tflops: 112.0, // FP16 tensor cores (paper: FP16 on V100)
            hbm_gbps: 900.0,
            kernel_launch_us: 4.5,
            time_scale: 1.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Platform> {
        match name {
            "a100-pcie" | "a100-pcie-4" => Some(Platform::a100_pcie(4)),
            "a100-pcie-8" => Some(Platform::a100_pcie(8)),
            "a100-2node" => Some(Platform::a100_two_node()),
            "v100-nvlink" => Some(Platform::v100_nvlink()),
            _ => None,
        }
    }

    pub fn total_gpus(&self) -> usize {
        self.gpus_per_node * self.nodes
    }

    /// Canonical encoding of the whole platform (topology, both links,
    /// compute capability) for the persistent profile cache: profiles are
    /// only reusable on a platform with an identical signature.
    pub fn signature(&self) -> String {
        format!(
            "{}/g{}n{}/intra[{}]/inter[{}]/tf{}hbm{}kl{}ts{}",
            self.name,
            self.gpus_per_node,
            self.nodes,
            self.intra.signature(),
            self.inter.signature(),
            self.peak_tflops,
            self.hbm_gbps,
            self.kernel_launch_us,
            self.time_scale
        )
    }

    /// Device memory capacity in bytes.
    pub fn mem_capacity(&self) -> u64 {
        let full: u64 = match self.name {
            "v100-nvlink" => 16 << 30,
            _ => 40 << 30,
        };
        (full as f64 / self.byte_scale()) as u64
    }

    fn byte_scale(&self) -> f64 {
        // scaled_testbed(sb, st) keeps sb/st encoded via time_scale & bw
        if self.time_scale > 1.0 {
            SCALE_BYTES
        } else {
            1.0
        }
    }

    /// A dimensionally-consistent miniature of this platform for the
    /// `scaled_for_eval` model sizes: message bytes shrink by `SCALE_BYTES`
    /// and kernel times by `SCALE_TIME`, so effective-bandwidth saturation,
    /// launch-overhead shares and compute/comm balance all match the
    /// full-scale testbed exactly (a pure unit change — see DESIGN.md §2).
    pub fn scaled_testbed(mut self) -> Platform {
        let sb = SCALE_BYTES;
        let st = SCALE_TIME;
        let scale_link = |l: &mut LinkModel| {
            l.peak_gbps *= st / sb;
            l.sat_bytes /= sb;
            l.launch_us /= st;
            l.step_us /= st;
        };
        scale_link(&mut self.intra);
        scale_link(&mut self.inter);
        self.hbm_gbps *= st / sb;
        self.kernel_launch_us /= st;
        self.time_scale = st;
        self
    }
}

/// `scaled_for_eval` shrinks hidden by 8 and seq by 8 ⇒ activation and
/// parameter bytes shrink ≈64×, matmul flops ≈512×.
pub const SCALE_BYTES: f64 = 64.0;
pub const SCALE_TIME: f64 = 512.0;

fn ethernet() -> LinkModel {
    LinkModel {
        peak_gbps: 11.0, // ~100 GbE effective
        sat_bytes: 8.0e6,
        launch_us: 25.0,
        step_us: 18.0,
        sendrecv_penalty: 2.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_bandwidth_saturates() {
        let l = Platform::a100_pcie(4).intra;
        let small = l.eff_bw_gbps(64e3);
        let big = l.eff_bw_gbps(256e6);
        assert!(small < 0.4 * l.peak_gbps, "small msgs inefficient: {small}");
        assert!(big > 0.95 * l.peak_gbps, "big msgs near peak: {big}");
    }

    #[test]
    fn nvlink_is_much_faster_than_pcie() {
        let p = Platform::a100_pcie(4).intra.eff_bw_gbps(64e6);
        let v = Platform::v100_nvlink().intra.eff_bw_gbps(64e6);
        assert!(v > 4.0 * p, "nvlink {v} vs pcie {p}");
    }

    #[test]
    fn signatures_distinguish_platforms_and_scales() {
        let a = Platform::a100_pcie(4).signature();
        let b = Platform::a100_pcie(8).signature();
        let v = Platform::v100_nvlink().signature();
        let s = Platform::a100_pcie(4).scaled_testbed().signature();
        assert_ne!(a, b);
        assert_ne!(a, v);
        assert_ne!(a, s, "scaled testbed must not hit full-scale cache entries");
        assert_eq!(a, Platform::a100_pcie(4).signature(), "signature is deterministic");
    }

    #[test]
    fn lookup_by_name() {
        for n in ["a100-pcie", "a100-pcie-8", "a100-2node", "v100-nvlink"] {
            assert!(Platform::by_name(n).is_some(), "{n}");
        }
        assert!(Platform::by_name("tpu-v9000").is_none());
    }
}
