//! Collective communication cost models (ring algorithms, NCCL-shaped).
//!
//! `bytes` is always the GLOBAL tensor size; each model applies its own
//! wire-volume factor. Time = launch + steps·α + wire_bytes / eff_bw(chunk).
//! The chunk size entering `eff_bw` is the per-step message — this is what
//! makes many small collectives slower than one fused big one at equal
//! volume (the §2.2/Fig. 2 effect).

use crate::spmd::CollKind;

use super::platform::LinkModel;

/// Time (µs) for one collective over `n` devices on `link`.
pub fn collective_time_us(kind: CollKind, bytes: u64, n: usize, link: &LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let b = bytes as f64;
    let nf = n as f64;
    let (wire, steps) = match kind {
        // ring allreduce: reduce-scatter + allgather phases
        CollKind::AllReduce => (2.0 * b * (nf - 1.0) / nf, 2 * (n - 1)),
        CollKind::AllGather | CollKind::ReduceScatter => (b * (nf - 1.0) / nf, n - 1),
        // pairwise exchange: every device sends (n-1)/n of its shard
        CollKind::AllToAll => (b * (nf - 1.0) / nf, n - 1),
        CollKind::Broadcast => (b, n - 1),
        CollKind::SendRecv => {
            // one pairwise hop, penalized on PCIe-like links
            let bw = link.eff_bw_gbps(b) / link.sendrecv_penalty;
            return link.launch_us + link.step_us + b / (bw * 1e3);
        }
    };
    let chunk = (wire / steps.max(1) as f64).max(1.0);
    let bw = link.eff_bw_gbps(chunk); // GB/s == bytes/µs ÷ 1e3
    link.launch_us + steps as f64 * link.step_us + wire / (bw * 1e3)
}

/// Achieved bus bandwidth (GB/s) implied by a measured collective time —
/// the Fig. 8 "utilized communication bandwidth" metric (bytes moved per
/// wall-clock second, NCCL busbw convention).
pub fn achieved_bandwidth_gbps(kind: CollKind, bytes: u64, n: usize, time_us: f64) -> f64 {
    if time_us <= 0.0 || n <= 1 {
        return 0.0;
    }
    let b = bytes as f64;
    let nf = n as f64;
    let wire = match kind {
        CollKind::AllReduce => 2.0 * b * (nf - 1.0) / nf,
        CollKind::AllGather | CollKind::ReduceScatter | CollKind::AllToAll => {
            b * (nf - 1.0) / nf
        }
        CollKind::Broadcast => b,
        CollKind::SendRecv => b,
    };
    wire / (time_us * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::platform::Platform;

    fn link() -> LinkModel {
        Platform::a100_pcie(4).intra
    }

    #[test]
    fn monotone_in_size() {
        let l = link();
        let mut last = 0.0;
        for mb in [1u64, 4, 16, 64, 256] {
            let t = collective_time_us(CollKind::AllReduce, mb << 20, 4, &l);
            assert!(t > last, "{mb}MB: {t} vs {last}");
            last = t;
        }
    }

    #[test]
    fn ring_allreduce_asymptotics() {
        // at huge sizes, time → 2(n-1)/n · bytes / peak
        let l = link();
        let bytes = 1u64 << 30;
        let t = collective_time_us(CollKind::AllReduce, bytes, 4, &l);
        let ideal = 2.0 * (bytes as f64) * 0.75 / (l.peak_gbps * 1e3);
        assert!((t / ideal - 1.0).abs() < 0.1, "t={t} ideal={ideal}");
    }

    #[test]
    fn reduce_scatter_is_half_an_allreduce() {
        let l = link();
        let bytes = 256u64 << 20;
        let ar = collective_time_us(CollKind::AllReduce, bytes, 4, &l);
        let rs = collective_time_us(CollKind::ReduceScatter, bytes, 4, &l);
        assert!((ar / rs - 2.0).abs() < 0.2, "ar={ar} rs={rs}");
    }

    #[test]
    fn fusion_beats_fragmentation_at_equal_volume() {
        // 64 × 1MB AllReduces vs 1 × 64MB — the §2.2 DP effect
        let l = link();
        let many: f64 =
            (0..64).map(|_| collective_time_us(CollKind::AllReduce, 1 << 20, 4, &l)).sum();
        let one = collective_time_us(CollKind::AllReduce, 64 << 20, 4, &l);
        assert!(many > 1.5 * one, "many={many} one={one}");
    }

    #[test]
    fn sendrecv_chain_is_slow_on_pcie() {
        // AllToAll as 3 sendrecvs vs native alltoall pricing
        let l = link();
        let native = collective_time_us(CollKind::AllToAll, 64 << 20, 4, &l);
        let dispatched: f64 = (0..3)
            .map(|_| collective_time_us(CollKind::SendRecv, 16 << 20, 4, &l))
            .sum();
        assert!(dispatched > 1.5 * native, "dispatched={dispatched} native={native}");
    }

    #[test]
    fn single_device_is_free() {
        assert_eq!(collective_time_us(CollKind::AllReduce, 1 << 30, 1, &link()), 0.0);
    }

    #[test]
    fn achieved_bw_sane() {
        let l = link();
        let bytes = 256u64 << 20;
        let t = collective_time_us(CollKind::AllReduce, bytes, 4, &l);
        let bw = achieved_bandwidth_gbps(CollKind::AllReduce, bytes, 4, t);
        assert!(bw > 0.5 * l.peak_gbps && bw <= l.peak_gbps * 1.01, "bw={bw}");
    }
}
