//! Cluster substrate: multi-GPU platforms with interconnect models and a
//! per-device timeline simulator for lowered SPMD programs.
//!
//! This replaces the paper's physical testbeds (8×A100-PCIe, 2×8×A100,
//! 4×V100-NVLink — §5.1) per the substitution rule in DESIGN.md §2. The
//! models capture the *structural* facts the paper's evaluation turns on:
//!
//!  * collective time is a nonlinear function of message size — fixed
//!    kernel-launch cost + α latency per algorithm step + size-dependent
//!    effective bandwidth that saturates only for multi-MB messages
//!    (why many small AllReduces lose to one big one, §2.2);
//!  * ring algorithm factors: AllReduce moves 2(n−1)/n of the tensor,
//!    AllGather/ReduceScatter (n−1)/n (why the RS rewrite halves cost);
//!  * SendRecv chains price each pairwise hop separately (why AllToAll
//!    collapses on PCIe, §5.7);
//!  * PCIe vs NVLink peak bandwidth differ ~10× (why config ranking
//!    changes across platforms, Fig. 7).

pub mod collective;
pub mod platform;
pub mod sim;

pub use collective::collective_time_us;
pub use platform::{LinkModel, Platform};
pub use sim::{
    simulate, simulate_pipeline, simulate_pipeline_memory, PipelineSchedule, SimReport,
    StageMemSpec,
};
