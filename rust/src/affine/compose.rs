//! Composition of affine dimension maps along operator chains
//! ("Constructing and Propagating Dependency", paper §3.2, Eq. 3–6).
//!
//! Composition degrades conservatively: any combination we cannot express
//! exactly becomes `All` (full-dimension dependence). Conservative means a
//! subgraph may be *under*-grouped into ParallelBlocks, never incorrectly
//! grouped — preserving the communication-free soundness invariant.

/// Per-output-dimension dependency on an input tensor's dimensions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DimDep {
    /// `b_{in_dim} = a` — pointwise (Table 1: elementwise / transpose).
    Point { in_dim: usize },
    /// `b = ⌊a/block⌋·block + k, 0 ≤ k < block` — block-local window (Eq. 3).
    Block { in_dim: usize, block: usize },
    /// depends on the whole input dimension (Table 1 `*`).
    All { in_dim: usize },
    /// no dependence (broadcast-created dim).
    Free,
    /// reshape split, high part: `b_{in_dim} = inner·a + lo`.
    SplitHi { in_dim: usize, inner: usize },
    /// reshape split, low (interleaved) part.
    SplitLo { in_dim: usize, inner: usize },
    /// reshape merge of input dims hi..=lo (row-major, |lo-part| = inner).
    Merge { hi: usize, lo: usize, inner: usize },
}

impl DimDep {
    /// The input dim this dep primarily touches (for All-degradation).
    pub fn primary_dim(&self) -> Option<usize> {
        match *self {
            DimDep::Point { in_dim }
            | DimDep::Block { in_dim, .. }
            | DimDep::All { in_dim }
            | DimDep::SplitHi { in_dim, .. }
            | DimDep::SplitLo { in_dim, .. } => Some(in_dim),
            DimDep::Merge { hi, .. } => Some(hi),
            DimDep::Free => None,
        }
    }
}

/// Affine dependency of a consumer tensor on a producer tensor,
/// one entry per consumer dim.
#[derive(Clone, Debug, PartialEq)]
pub struct DimMap {
    pub deps: Vec<DimDep>,
    pub in_rank: usize,
}

impl DimMap {
    pub fn identity(rank: usize) -> DimMap {
        DimMap {
            deps: (0..rank).map(|d| DimDep::Point { in_dim: d }).collect(),
            in_rank: rank,
        }
    }

    /// True if some consumer dim depends pointwise/block-wise on `in_dim`
    /// (i.e. a partition of `in_dim` could propagate).
    pub fn carries(&self, in_dim: usize) -> bool {
        self.deps.iter().any(|d| {
            matches!(d,
                DimDep::Point { in_dim: i } | DimDep::Block { in_dim: i, .. }
                | DimDep::SplitHi { in_dim: i, .. }
                if *i == in_dim
            ) || matches!(d, DimDep::Merge { hi, .. } if *hi == in_dim)
        })
    }
}

/// Compose: `outer` maps Z-dims → Y-dims, `inner` maps Y-dims → X-dims;
/// result maps Z-dims → X-dims (path Z ← Y ← X in consumer order).
pub fn compose(outer: &DimMap, inner: &DimMap) -> DimMap {
    let deps = outer
        .deps
        .iter()
        .map(|zdep| match *zdep {
            DimDep::Free => DimDep::Free,
            DimDep::Point { in_dim } => inner_dep(inner, in_dim),
            DimDep::Block { in_dim, block } => match inner_dep(inner, in_dim) {
                DimDep::Point { in_dim: x } => DimDep::Block { in_dim: x, block },
                DimDep::Block { in_dim: x, block: b2 } => {
                    DimDep::Block { in_dim: x, block: block.max(b2) }
                }
                DimDep::Free => DimDep::Free,
                d => degrade(d),
            },
            DimDep::All { in_dim } => match inner_dep(inner, in_dim) {
                DimDep::Free => DimDep::Free,
                d => degrade_all(d),
            },
            DimDep::SplitHi { in_dim, inner: k } => match inner_dep(inner, in_dim) {
                DimDep::Point { in_dim: x } => DimDep::SplitHi { in_dim: x, inner: k },
                DimDep::Free => DimDep::Free,
                d => degrade(d),
            },
            DimDep::SplitLo { in_dim, inner: k } => match inner_dep(inner, in_dim) {
                DimDep::Point { in_dim: x } => DimDep::SplitLo { in_dim: x, inner: k },
                DimDep::Free => DimDep::Free,
                d => degrade(d),
            },
            DimDep::Merge { hi, lo, inner: k } => {
                match (inner_dep(inner, hi), inner_dep(inner, lo)) {
                    (DimDep::Point { in_dim: xh }, DimDep::Point { in_dim: xl }) => {
                        DimDep::Merge { hi: xh, lo: xl, inner: k }
                    }
                    (dh, _) => degrade(dh),
                }
            }
        })
        .collect();
    DimMap { deps, in_rank: inner.in_rank }
}

fn inner_dep(inner: &DimMap, y_dim: usize) -> DimDep {
    inner.deps.get(y_dim).copied().unwrap_or(DimDep::Free)
}

fn degrade(d: DimDep) -> DimDep {
    match d.primary_dim() {
        Some(i) => DimDep::All { in_dim: i },
        None => DimDep::Free,
    }
}

fn degrade_all(d: DimDep) -> DimDep {
    degrade(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::Prop;
    use crate::util::Pcg64;

    #[test]
    fn identity_composes_neutrally() {
        let id = DimMap::identity(3);
        let m = DimMap {
            deps: vec![
                DimDep::Point { in_dim: 2 },
                DimDep::All { in_dim: 0 },
                DimDep::Free,
            ],
            in_rank: 3,
        };
        assert_eq!(compose(&id, &m).deps, m.deps);
        assert_eq!(compose(&m, &id).deps, m.deps);
    }

    #[test]
    fn point_chains_stay_point() {
        // Z←Y: perm (1,0); Y←X: perm (1,0) ⇒ Z←X identity
        let swap = DimMap {
            deps: vec![DimDep::Point { in_dim: 1 }, DimDep::Point { in_dim: 0 }],
            in_rank: 2,
        };
        let c = compose(&swap, &swap);
        assert_eq!(c.deps, DimMap::identity(2).deps);
    }

    #[test]
    fn all_absorbs() {
        let all0 = DimMap {
            deps: vec![DimDep::All { in_dim: 0 }],
            in_rank: 1,
        };
        let pt = DimMap {
            deps: vec![DimDep::Point { in_dim: 0 }],
            in_rank: 1,
        };
        assert_eq!(compose(&all0, &pt).deps[0], DimDep::All { in_dim: 0 });
        assert_eq!(compose(&pt, &all0).deps[0], DimDep::All { in_dim: 0 });
    }

    #[test]
    fn block_of_block_keeps_coarser_block() {
        let b4 = DimMap {
            deps: vec![DimDep::Block { in_dim: 0, block: 4 }],
            in_rank: 1,
        };
        let b8 = DimMap {
            deps: vec![DimDep::Block { in_dim: 0, block: 8 }],
            in_rank: 1,
        };
        assert_eq!(compose(&b4, &b8).deps[0], DimDep::Block { in_dim: 0, block: 8 });
    }

    /// Property: composition is associative on randomly generated maps.
    #[test]
    fn prop_compose_associative() {
        fn random_map(rng: &mut Pcg64, out_rank: usize, in_rank: usize) -> DimMap {
            let deps = (0..out_rank)
                .map(|_| {
                    let d = rng.below(in_rank as u64) as usize;
                    match rng.below(5) {
                        0 => DimDep::Point { in_dim: d },
                        1 => DimDep::Block { in_dim: d, block: 1 << rng.below(4) },
                        2 => DimDep::All { in_dim: d },
                        3 => DimDep::Free,
                        _ => DimDep::SplitHi { in_dim: d, inner: 1 << rng.below(3) },
                    }
                })
                .collect();
            DimMap { deps, in_rank }
        }
        Prop::default().check("compose associative", |rng| {
            let r = 1 + rng.below(4) as usize;
            let a = random_map(rng, r, r);
            let b = random_map(rng, r, r);
            let c = random_map(rng, r, r);
            let left = compose(&compose(&a, &b), &c);
            let right = compose(&a, &compose(&b, &c));
            // associativity holds up to conservative degradation: both sides
            // must agree on the primary dim and on exact (Point) entries.
            for (l, rr) in left.deps.iter().zip(&right.deps) {
                assert_eq!(l.primary_dim(), rr.primary_dim(), "{a:?} {b:?} {c:?}");
                if matches!(l, DimDep::Point { .. }) || matches!(rr, DimDep::Point { .. }) {
                    assert_eq!(l, rr, "{a:?} {b:?} {c:?}");
                }
            }
        });
    }
}
