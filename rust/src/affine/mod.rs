//! Affine dependency analysis (paper §3.2, Table 1).
//!
//! Each operator gets an affine expression mapping output-element indices to
//! the input elements they depend on. We represent the per-dimension
//! structure of that affine map ([`DimDep`]): pointwise, block-local
//! (`b = ⌊a/d⌋·d + k`, Eq. 2/3), full-dimension (`*` in Table 1), reshape
//! split/merge factors, or free (broadcast). Composition of these maps along
//! operator chains is what lets CFP decide whether a tensor partition
//! propagates through a subgraph without communication — the
//! parallelism-preserving property that defines ParallelBlocks — and what
//! the segment fingerprints (§4.1) are built from.

pub mod compose;
pub mod propagate;

pub use compose::{compose, DimDep, DimMap};
pub use propagate::{propagate, CoShard, Prop};

use crate::graph::{Graph, OpId, OpKind};

/// Affine dependency of `op`'s output on its `input_index`-th input
/// (Table 1 of the paper).
pub fn op_dim_map(g: &Graph, op: OpId, input_index: usize) -> DimMap {
    let o = &g.ops[op];
    let input = o.inputs[input_index];
    let in_shape = g.shape(input).to_vec();
    let out_shape = o.shape.clone();
    match &o.kind {
        OpKind::Param { .. } | OpKind::Constant { .. } | OpKind::Rng => {
            DimMap { deps: vec![], in_rank: 0 }
        }
        // Elementwise: identity transformation
        OpKind::Elem(_) => DimMap {
            deps: (0..out_shape.len()).map(|d| DimDep::Point { in_dim: d }).collect(),
            in_rank: in_shape.len(),
        },
        OpKind::Transpose { perm } => DimMap {
            deps: perm.iter().map(|&p| DimDep::Point { in_dim: p }).collect(),
            in_rank: in_shape.len(),
        },
        OpKind::Broadcast { dims } => DimMap {
            deps: (0..out_shape.len())
                .map(|d| match dims.iter().position(|&m| m == d) {
                    Some(i) => DimDep::Point { in_dim: i },
                    None => DimDep::Free,
                })
                .collect(),
            in_rank: in_shape.len(),
        },
        OpKind::Reduce { dims, .. } => {
            // out dim d corresponds to the d-th kept input dim; reduced
            // dims are `*` (All) in Table-1 terms but don't appear in the
            // output index space, so the map only carries kept dims.
            let kept: Vec<usize> =
                (0..in_shape.len()).filter(|i| !dims.contains(i)).collect();
            DimMap {
                deps: kept.iter().map(|&i| DimDep::Point { in_dim: i }).collect(),
                in_rank: in_shape.len(),
            }
        }
        OpKind::Reshape => reshape_map(&in_shape, &out_shape),
        OpKind::Dot(d) => {
            let b = d.batch;
            let deps = (0..out_shape.len())
                .map(|dim| {
                    if dim < b {
                        DimDep::Point { in_dim: dim }
                    } else if dim == b {
                        // M from lhs / contracted on rhs
                        if input_index == 0 {
                            DimDep::Point { in_dim: b }
                        } else {
                            DimDep::All { in_dim: b }
                        }
                    } else {
                        // N from rhs / contracted on lhs
                        if input_index == 1 {
                            DimDep::Point { in_dim: b + 1 }
                        } else {
                            DimDep::All { in_dim: b + 1 }
                        }
                    }
                })
                .collect();
            DimMap { deps, in_rank: in_shape.len() }
        }
        OpKind::Gather => {
            if input_index == 0 {
                // table: out = idx_dims ++ table[1:]; idx dims select rows
                let idx_rank = out_shape.len() - (in_shape.len() - 1);
                let deps = (0..out_shape.len())
                    .map(|d| {
                        if d < idx_rank {
                            DimDep::All { in_dim: 0 }
                        } else {
                            DimDep::Point { in_dim: d - idx_rank + 1 }
                        }
                    })
                    .collect();
                DimMap { deps, in_rank: in_shape.len() }
            } else {
                let idx_rank = in_shape.len();
                let deps = (0..out_shape.len())
                    .map(|d| {
                        if d < idx_rank {
                            DimDep::Point { in_dim: d }
                        } else {
                            DimDep::Free
                        }
                    })
                    .collect();
                DimMap { deps, in_rank: in_shape.len() }
            }
        }
        OpKind::Route => {
            let out_rank = out_shape.len();
            let in_rank = in_shape.len();
            DimMap {
                deps: (0..out_rank)
                    .map(|d| {
                        if d + 1 == out_rank {
                            DimDep::Point { in_dim: in_rank - 1 }
                        } else {
                            DimDep::All { in_dim: 0 }
                        }
                    })
                    .collect(),
                in_rank,
            }
        }
        OpKind::Slice { dim, .. } => DimMap {
            deps: (0..out_shape.len())
                .map(|d| DimDep::Point { in_dim: if d < *dim { d } else { d + 1 } })
                .collect(),
            in_rank: in_shape.len(),
        },
        OpKind::Pad { dim, .. } => DimMap {
            deps: (0..out_shape.len())
                .map(|d| {
                    if d == *dim {
                        DimDep::Free
                    } else {
                        DimDep::Point { in_dim: if d < *dim { d } else { d - 1 } }
                    }
                })
                .collect(),
            in_rank: in_shape.len(),
        },
        OpKind::Scatter { .. } => {
            // grad-of-gather: every output element may receive updates from
            // any index position — conservatively All on the update dims.
            let deps = (0..out_shape.len())
                .map(|d| {
                    if d == 0 {
                        DimDep::All { in_dim: 0 }
                    } else {
                        DimDep::Point { in_dim: d }
                    }
                })
                .collect();
            DimMap { deps, in_rank: in_shape.len() }
        }
    }
}

/// Reshape dimension-group factorization: split input and output dims into
/// minimal groups with equal element products (row-major correspondence).
/// Returns per-output-dim deps: the leading dim of each group maps
/// `SplitHi`-style to the group's leading input dim; inner dims are
/// interleaved (`SplitLo`) and merges are recorded.
pub fn reshape_map(in_shape: &[usize], out_shape: &[usize]) -> DimMap {
    let groups = reshape_groups(in_shape, out_shape);
    let mut deps = vec![DimDep::Free; out_shape.len()];
    for gr in &groups {
        let (i0, i1, j0, j1) = (gr.in_start, gr.in_end, gr.out_start, gr.out_end);
        if i1 - i0 == 1 && j1 - j0 == 1 {
            deps[j0] = DimDep::Point { in_dim: i0 };
        } else if i1 - i0 == 1 {
            // split: input dim i0 → output dims j0..j1
            let mut inner: usize = out_shape[j0 + 1..j1].iter().product();
            for j in j0..j1 {
                deps[j] = if j == j0 {
                    DimDep::SplitHi { in_dim: i0, inner }
                } else {
                    DimDep::SplitLo { in_dim: i0, inner }
                };
                if j + 1 < j1 {
                    inner /= out_shape[j + 1];
                }
            }
        } else if j1 - j0 == 1 {
            // merge: input dims i0..i1 → output dim j0
            let inner: usize = in_shape[i0 + 1..i1].iter().product();
            deps[j0] = DimDep::Merge { hi: i0, lo: i1 - 1, inner };
        } else {
            // general regrouping — conservative: all outs depend on all ins
            for j in j0..j1 {
                deps[j] = DimDep::All { in_dim: i0 };
            }
        }
    }
    DimMap { deps, in_rank: in_shape.len() }
}

pub struct ReshapeGroup {
    pub in_start: usize,
    pub in_end: usize,
    pub out_start: usize,
    pub out_end: usize,
}

/// Minimal aligned groups between two shapes of equal numel.
pub fn reshape_groups(in_shape: &[usize], out_shape: &[usize]) -> Vec<ReshapeGroup> {
    let mut groups = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    while i < in_shape.len() || j < out_shape.len() {
        let (i0, j0) = (i, j);
        let mut pi: u128 = 1;
        let mut pj: u128 = 1;
        // always consume at least one dim on each side (when available)
        if i < in_shape.len() {
            pi *= in_shape[i] as u128;
            i += 1;
        }
        if j < out_shape.len() {
            pj *= out_shape[j] as u128;
            j += 1;
        }
        while pi != pj {
            if pi < pj {
                pi *= in_shape[i] as u128;
                i += 1;
            } else {
                pj *= out_shape[j] as u128;
                j += 1;
            }
        }
        // absorb trailing 1s
        while i < in_shape.len() && in_shape[i] == 1 {
            i += 1;
        }
        while j < out_shape.len() && out_shape[j] == 1 {
            j += 1;
        }
        groups.push(ReshapeGroup { in_start: i0, in_end: i, out_start: j0, out_end: j });
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ElemOp, ParamClass};

    #[test]
    fn elementwise_is_identity() {
        let mut g = Graph::new();
        let x = g.param("x", vec![2, 3], ParamClass::Input);
        let y = g.unary(ElemOp::Exp, x, "y");
        let m = op_dim_map(&g, y, 0);
        assert_eq!(m.deps, vec![DimDep::Point { in_dim: 0 }, DimDep::Point { in_dim: 1 }]);
    }

    #[test]
    fn transpose_permutes() {
        let mut g = Graph::new();
        let x = g.param("x", vec![2, 3, 4], ParamClass::Input);
        let y = g.transpose(x, vec![2, 0, 1], "t");
        let m = op_dim_map(&g, y, 0);
        assert_eq!(
            m.deps,
            vec![
                DimDep::Point { in_dim: 2 },
                DimDep::Point { in_dim: 0 },
                DimDep::Point { in_dim: 1 }
            ]
        );
    }

    #[test]
    fn dot_marks_contraction_all() {
        let mut g = Graph::new();
        let a = g.param("a", vec![4, 8], ParamClass::Input);
        let b = g.param("b", vec![8, 16], ParamClass::Input);
        let c = g.matmul(a, b, "c");
        let ml = op_dim_map(&g, c, 0);
        assert_eq!(ml.deps[0], DimDep::Point { in_dim: 0 }); // M from lhs
        assert_eq!(ml.deps[1], DimDep::All { in_dim: 1 });   // N sweeps lhs K
        let mr = op_dim_map(&g, c, 1);
        assert_eq!(mr.deps[0], DimDep::All { in_dim: 0 });   // M sweeps rhs K
        assert_eq!(mr.deps[1], DimDep::Point { in_dim: 1 }); // N from rhs
    }

    #[test]
    fn reshape_split_and_merge() {
        // (6, 4) -> (2, 3, 4): dim0 split, dim2 pointwise
        let m = reshape_map(&[6, 4], &[2, 3, 4]);
        assert_eq!(m.deps[0], DimDep::SplitHi { in_dim: 0, inner: 3 });
        assert_eq!(m.deps[1], DimDep::SplitLo { in_dim: 0, inner: 1 });
        assert_eq!(m.deps[2], DimDep::Point { in_dim: 1 });
        // (2, 3, 4) -> (6, 4): merge
        let m2 = reshape_map(&[2, 3, 4], &[6, 4]);
        assert_eq!(m2.deps[0], DimDep::Merge { hi: 0, lo: 1, inner: 3 });
        assert_eq!(m2.deps[1], DimDep::Point { in_dim: 2 });
    }

    #[test]
    fn reshape_groups_align() {
        let gs = reshape_groups(&[4, 6, 5], &[24, 5]);
        assert_eq!(gs.len(), 2);
        assert_eq!((gs[0].in_start, gs[0].in_end), (0, 2));
        assert_eq!((gs[0].out_start, gs[0].out_end), (0, 1));
    }

    #[test]
    fn gather_table_rows_are_all() {
        let mut g = Graph::new();
        let t = g.param("t", vec![100, 8], ParamClass::Weight);
        let i = g.param("tokens", vec![4, 5], ParamClass::Input);
        let y = g.gather(t, i, "g");
        let m = op_dim_map(&g, y, 0);
        assert_eq!(m.deps[0], DimDep::All { in_dim: 0 });
        assert_eq!(m.deps[2], DimDep::Point { in_dim: 1 });
        let mi = op_dim_map(&g, y, 1);
        assert_eq!(mi.deps[0], DimDep::Point { in_dim: 0 });
        assert_eq!(mi.deps[2], DimDep::Free);
    }
}
