//! Partition propagation through single operators (Eq. 2 instantiated).
//!
//! Given an operator, one of its inputs sharded on a dimension into P
//! parts, decide where the partition lands on the output — or whether it
//! is *blocked* (propagating it would require communication). This is the
//! predicate `Check user, PB with Eq.(2)` in Algorithm 1, and the kernel
//! of SPMD sharding inference in `spmd::lower`.

use crate::graph::{Graph, OpId, OpKind};

use super::reshape_groups;

/// Sharding requirement imposed on a *sibling* input for the propagation
/// to stay communication-free (e.g. Dot batch dims must be co-sharded;
/// elementwise siblings must be identically sharded).
#[derive(Clone, Debug, PartialEq)]
pub struct CoShard {
    pub input_index: usize,
    /// Some(dim): sibling must be sharded on `dim`; None: replicated.
    pub dim: Option<usize>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Prop {
    /// Partition propagates to output dim `out_dim` without communication.
    To { out_dim: usize, co_shards: Vec<CoShard> },
    /// Propagation requires communication (contracted/reduced/interleaved).
    Blocked,
}

/// Propagate a sharding of `op.inputs[input_index]` dim `in_dim` into `parts`
/// shards through `op`.
pub fn propagate(g: &Graph, op: OpId, input_index: usize, in_dim: usize, parts: usize) -> Prop {
    let o = &g.ops[op];
    let in_shape = g.shape(o.inputs[input_index]);
    if in_dim >= in_shape.len() || in_shape[in_dim] % parts != 0 {
        return Prop::Blocked;
    }
    let to = |out_dim: usize, co: Vec<CoShard>| -> Prop {
        // Eq. 2 divisibility on the output side
        if o.shape[out_dim] % parts == 0 {
            Prop::To { out_dim, co_shards: co }
        } else {
            Prop::Blocked
        }
    };
    match &o.kind {
        OpKind::Param { .. } | OpKind::Constant { .. } | OpKind::Rng => Prop::Blocked,
        OpKind::Elem(_) => {
            let co = (0..o.inputs.len())
                .filter(|&i| i != input_index)
                .map(|i| CoShard { input_index: i, dim: Some(in_dim) })
                .collect();
            to(in_dim, co)
        }
        OpKind::Transpose { perm } => {
            let out_dim = perm.iter().position(|&p| p == in_dim).unwrap();
            to(out_dim, vec![])
        }
        OpKind::Broadcast { dims } => to(dims[in_dim], vec![]),
        OpKind::Reduce { dims, .. } => {
            if dims.contains(&in_dim) {
                Prop::Blocked // partial reduction ⇒ AllReduce
            } else {
                let out_dim = in_dim - dims.iter().filter(|&&d| d < in_dim).count();
                to(out_dim, vec![])
            }
        }
        OpKind::Reshape => {
            let out_shape = &o.shape;
            for gr in reshape_groups(in_shape, out_shape) {
                if (gr.in_start..gr.in_end).contains(&in_dim) {
                    // only the leading dim of a group keeps contiguous shards
                    if in_dim == gr.in_start
                        && gr.out_start < out_shape.len()
                        && out_shape[gr.out_start] % parts == 0
                        && in_shape[in_dim] % parts == 0
                    {
                        return to(gr.out_start, vec![]);
                    }
                    return Prop::Blocked;
                }
            }
            Prop::Blocked
        }
        OpKind::Dot(d) => {
            let b = d.batch;
            let other = 1 - input_index;
            if in_dim < b {
                // batch dim: sibling must be co-sharded on the same batch dim
                to(in_dim, vec![CoShard { input_index: other, dim: Some(in_dim) }])
            } else if input_index == 0 && in_dim == b {
                // M: rhs replicated
                to(b, vec![CoShard { input_index: other, dim: None }])
            } else if input_index == 1 && in_dim == b + 1 {
                // N: lhs replicated
                to(b + 1, vec![CoShard { input_index: other, dim: None }])
            } else {
                // contraction dim ⇒ partial sums ⇒ AllReduce
                Prop::Blocked
            }
        }
        OpKind::Gather => {
            if input_index == 0 {
                // table rows sharded ⇒ lookups cross shards
                if in_dim == 0 {
                    Prop::Blocked
                } else {
                    let idx_rank = o.shape.len() - (in_shape.len() - 1);
                    to(idx_rank + in_dim - 1, vec![])
                }
            } else {
                to(in_dim, vec![])
            }
        }
        OpKind::Route => {
            let in_rank = in_shape.len();
            if in_dim + 1 == in_rank {
                to(o.shape.len() - 1, vec![])
            } else {
                Prop::Blocked // token/expert dims cross only via All-to-All
            }
        }
        OpKind::Slice { dim, .. } => {
            if in_dim == *dim {
                Prop::Blocked
            } else {
                to(if in_dim < *dim { in_dim } else { in_dim - 1 }, vec![])
            }
        }
        OpKind::Pad { dim, .. } => {
            to(if in_dim < *dim { in_dim } else { in_dim + 1 }, vec![])
        }
        OpKind::Scatter { .. } => {
            // updates sharded along index dims ⇒ partial tables ⇒ AllReduce;
            // trailing (feature) dims propagate.
            if input_index == 1 && in_dim >= 1 {
                let idx_rank = g.shape(o.inputs[0]).len();
                if in_dim >= idx_rank {
                    return to(in_dim - idx_rank + 1, vec![]);
                }
                Prop::Blocked
            } else {
                Prop::Blocked
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ElemOp, ParamClass, ReduceKind};

    fn simple_graph() -> (Graph, OpId, OpId) {
        let mut g = Graph::new();
        let a = g.param("a", vec![8, 16], ParamClass::Input);
        let b = g.param("b", vec![16, 32], ParamClass::Weight);
        let c = g.matmul(a, b, "c");
        (g, a, c)
    }

    #[test]
    fn dot_m_dim_propagates_with_replicated_rhs() {
        let (g, _, c) = simple_graph();
        match propagate(&g, c, 0, 0, 4) {
            Prop::To { out_dim, co_shards } => {
                assert_eq!(out_dim, 0);
                assert_eq!(co_shards, vec![CoShard { input_index: 1, dim: None }]);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn dot_contraction_blocked() {
        let (g, _, c) = simple_graph();
        assert_eq!(propagate(&g, c, 0, 1, 4), Prop::Blocked);
        assert_eq!(propagate(&g, c, 1, 0, 4), Prop::Blocked);
    }

    #[test]
    fn dot_batch_requires_co_shard() {
        let mut g = Graph::new();
        let a = g.param("a", vec![4, 8, 16], ParamClass::Input);
        let b = g.param("b", vec![4, 16, 8], ParamClass::Input);
        let c = g.dot(a, b, 1, "bmm");
        match propagate(&g, c, 0, 0, 2) {
            Prop::To { out_dim, co_shards } => {
                assert_eq!(out_dim, 0);
                assert_eq!(co_shards, vec![CoShard { input_index: 1, dim: Some(0) }]);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn indivisible_parts_blocked() {
        let (g, _, c) = simple_graph();
        assert_eq!(propagate(&g, c, 0, 0, 3), Prop::Blocked); // 8 % 3 != 0
    }

    #[test]
    fn reduce_blocks_reduced_dim_shifts_kept() {
        let mut g = Graph::new();
        let x = g.param("x", vec![4, 8, 16], ParamClass::Input);
        let r = g.reduce(x, vec![1], ReduceKind::Sum, "r");
        assert_eq!(propagate(&g, r, 0, 1, 2), Prop::Blocked);
        match propagate(&g, r, 0, 2, 4) {
            Prop::To { out_dim, .. } => assert_eq!(out_dim, 1),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn reshape_leading_dim_of_group_propagates() {
        let mut g = Graph::new();
        let x = g.param("x", vec![8, 16, 32], ParamClass::Input);
        let r = g.reshape(x, vec![128, 32], "merge");
        // dim 0 leads the (8,16)→(128) group
        match propagate(&g, r, 0, 0, 4) {
            Prop::To { out_dim, .. } => assert_eq!(out_dim, 0),
            p => panic!("{p:?}"),
        }
        // dim 1 is interleaved in the merge → blocked
        assert_eq!(propagate(&g, r, 0, 1, 4), Prop::Blocked);
        // dim 2 is its own group
        match propagate(&g, r, 0, 2, 4) {
            Prop::To { out_dim, .. } => assert_eq!(out_dim, 1),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn elementwise_requires_siblings_co_sharded() {
        let mut g = Graph::new();
        let a = g.param("a", vec![8, 8], ParamClass::Input);
        let b = g.param("b", vec![8, 8], ParamClass::Input);
        let s = g.binary(ElemOp::Add, a, b, "s");
        match propagate(&g, s, 0, 1, 2) {
            Prop::To { out_dim, co_shards } => {
                assert_eq!(out_dim, 1);
                assert_eq!(co_shards, vec![CoShard { input_index: 1, dim: Some(1) }]);
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn gather_table_feature_dim_propagates() {
        let mut g = Graph::new();
        let t = g.param("t", vec![100, 64], ParamClass::Weight);
        let i = g.param("tokens", vec![4, 8], ParamClass::Input);
        let y = g.gather(t, i, "g");
        assert_eq!(propagate(&g, y, 0, 0, 4), Prop::Blocked);
        match propagate(&g, y, 0, 1, 4) {
            Prop::To { out_dim, .. } => assert_eq!(out_dim, 2),
            p => panic!("{p:?}"),
        }
        match propagate(&g, y, 1, 0, 2) {
            Prop::To { out_dim, .. } => assert_eq!(out_dim, 0),
            p => panic!("{p:?}"),
        }
    }
}
