//! Property tests for the cache subsystem's two load-bearing invariants:
//! fingerprints are deterministic (a cache keyed on them is sound) and
//! sensitive to structural change (a cache keyed on them is safe), and
//! profile databases/caches survive a JSON save→load round trip exactly
//! (a warm run is bit-identical to its cold run).

use cfp::cluster::Platform;
use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::profiler::{profile_model, profile_model_cached, ProfileCache, ProfileOptions};
use cfp::segment::{extract_segments, fingerprint_digest};
use cfp::spmd::Mesh;
use cfp::util::proptest::Prop as Harness;
use cfp::util::{Json, Pcg64};

fn random_model(rng: &mut Pcg64) -> ModelCfg {
    let mut cfg = ModelCfg::preset(*rng.choice(&["gpt-tiny", "moe-tiny"]));
    cfg.layers = 1 + rng.below(3) as usize;
    cfg.seq = *rng.choice(&[16usize, 32]);
    cfg.batch = *rng.choice(&[4usize, 8]);
    cfg
}

fn fingerprints(cfg: &ModelCfg, parts: usize) -> Vec<String> {
    let g = build_training(cfg);
    let bs = build_parallel_blocks(&g, parts);
    let ss = extract_segments(&g, &bs);
    ss.unique.iter().map(|u| u.fingerprint.clone()).collect()
}

/// Rebuilding the same model from scratch yields byte-identical
/// fingerprints — the soundness precondition for keying a persistent
/// cache on them (stale keys would silently re-profile; unstable keys
/// would poison lookups).
#[test]
fn prop_fingerprints_deterministic_across_rebuilds() {
    Harness::fuzz(16, 0xF1CA).check("fingerprint determinism", |rng| {
        let cfg = random_model(rng);
        let parts = *rng.choice(&[2usize, 4]);
        let a = fingerprints(&cfg, parts);
        let b = fingerprints(&cfg, parts);
        assert_eq!(a, b, "rebuild changed fingerprints");
        let da: Vec<u64> = a.iter().map(|f| fingerprint_digest(f)).collect();
        let db_: Vec<u64> = b.iter().map(|f| fingerprint_digest(f)).collect();
        assert_eq!(da, db_);
    });
}

/// Structurally different segments (changed batch/seq/hidden) never share
/// a fingerprint vector — the safety precondition: a cache entry can only
/// be reused where re-profiling would reproduce it.
#[test]
fn prop_fingerprints_differ_for_structurally_different_segments() {
    Harness::fuzz(16, 0xD1FF).check("fingerprint sensitivity", |rng| {
        let cfg = random_model(rng);
        let mut mutated = cfg.clone();
        match rng.below(3) {
            0 => mutated.batch *= 2,
            1 => mutated.seq *= 2,
            _ => {
                mutated.hidden *= 2;
                mutated.ffn *= 2;
            }
        }
        let parts = 2;
        let a = fingerprints(&cfg, parts);
        let b = fingerprints(&mutated, parts);
        assert_ne!(a, b, "structural change must change some fingerprint");
        // within one model, unique segments are pairwise distinct by
        // construction — the digests should separate them too
        let mut digests: Vec<u64> = a.iter().map(|f| fingerprint_digest(f)).collect();
        digests.sort_unstable();
        digests.dedup();
        assert_eq!(digests.len(), a.len(), "digest collision within a model");
    });
}

/// ProfileDb JSON round trip is exact (floats are written in shortest
/// round-trippable form), and a ProfileCache reloaded from its JSON file
/// serves a warm run that reproduces the cold ProfileDb bit-for-bit.
#[test]
fn prop_profile_db_and_cache_round_trip() {
    Harness::fuzz(6, 0x5A7E).check("profile round trip", |rng| {
        let cfg = random_model(rng);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 2);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(2));

        // db → json text → db
        let mut cache = ProfileCache::in_memory();
        let cold = profile_model_cached(&g, &bs, &ss, &opts, Some(&mut cache));
        let text = cold.to_json().to_string();
        let parsed = cfp::profiler::ProfileDb::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, cold, "ProfileDb JSON round trip must be exact");

        // cache → json text → cache → warm run
        let reloaded =
            ProfileCache::from_json(&Json::parse(&cache.to_json().to_string()).unwrap())
                .expect("cache json reparses");
        let mut reloaded = reloaded;
        let warm = profile_model_cached(&g, &bs, &ss, &opts, Some(&mut reloaded));
        assert_eq!(warm.stats.cache_misses, 0);
        assert_eq!(warm.stats.profile_wall_s, 0.0);
        assert_eq!(warm.segments, cold.segments);
        assert_eq!(warm.reshard, cold.reshard);

        // and an uncached profile of the same model agrees with the cold one
        let plain = profile_model(&g, &bs, &ss, &opts);
        assert_eq!(plain.segments, cold.segments);
    });
}
