//! Integration tests over the real PJRT runtime + AOT artifacts.
//! These require `make artifacts`; they skip (with a note) otherwise.

use cfp::cluster::Platform;
use cfp::runtime::Runtime;
use cfp::trainer::Trainer;
use cfp::util::Pcg64;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn layer_artifacts_execute_and_are_finite() {
    let Some(rt) = runtime() else { return };
    let mut rng = Pcg64::new(3);
    for name in ["layer_gpt_full", "layer_gpt_tp2", "layer_llama_full", "layer_llama_tp4"] {
        if rt.meta(name).is_none() {
            continue;
        }
        let inputs = rt.random_inputs(name, &mut rng).unwrap();
        let out = rt.run(name, &inputs).unwrap();
        let v = out[0].to_vec::<f32>().unwrap();
        assert!(v.iter().all(|x| x.is_finite()), "{name} produced non-finite values");
    }
}

#[test]
fn dp_shard_time_scales_with_batch() {
    // layer_gpt_full (batch 8) should take roughly ≥ the dp4 shard (batch 2):
    // real measured compute times back the simulator's T_P scaling
    let Some(rt) = runtime() else { return };
    if rt.meta("layer_gpt_full").is_none() || rt.meta("layer_gpt_dp4").is_none() {
        return;
    }
    let full = rt.measure("layer_gpt_full", 2, 5).unwrap();
    let quarter = rt.measure("layer_gpt_dp4", 2, 5).unwrap();
    assert!(
        full > quarter * 0.9,
        "full-batch layer ({full:.4}s) should not be faster than the b/4 shard ({quarter:.4}s)"
    );
}

#[test]
fn calibration_efficiency_increases_with_size() {
    let Some(rt) = runtime() else { return };
    let small = rt.measure("calib_matmul_64x64x64", 2, 3).unwrap();
    let big = rt.measure("calib_matmul_1024x1024x1024", 2, 3).unwrap();
    let f_small = 2.0 * 64f64.powi(3) / small;
    let f_big = 2.0 * 1024f64.powi(3) / big;
    assert!(
        f_big > 2.0 * f_small,
        "bigger matmuls must achieve higher flops/s: {f_small:.2e} vs {f_big:.2e}"
    );
}

#[test]
fn calibrated_model_feeds_simulator() {
    let Some(rt) = runtime() else { return };
    let platform = Platform::a100_pcie(4);
    let cm = rt.calibrate_compute(&platform).unwrap();
    // monotone + sane range
    assert!(cm.time_us(1 << 16, 1 << 10) < cm.time_us(1 << 30, 1 << 10));
    assert!(cm.efficiency(1 << 30) > cm.efficiency(1 << 12));
}

#[test]
fn train_step_artifact_loss_curve_falls() {
    let Some(rt) = runtime() else { return };
    if rt.meta("train_step_gpt").is_none() {
        return;
    }
    let mut tr = Trainer::new(&rt, "train_step_gpt", 123).unwrap();
    let mut losses = Vec::new();
    for _ in 0..12 {
        losses.push(tr.step(0.08).unwrap());
    }
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(first.is_finite() && last.is_finite());
    assert!(
        last < first,
        "12 steps should already reduce loss: {first:.3} → {last:.3}"
    );
}

#[test]
fn manifest_matches_artifacts_on_disk() {
    let Some(rt) = runtime() else { return };
    for m in &rt.manifest {
        let path = std::path::Path::new("artifacts").join(&m.file);
        assert!(path.exists(), "{} missing", m.file);
        assert!(!m.inputs.is_empty() || m.kind == "const", "{} has no inputs", m.name);
    }
}
