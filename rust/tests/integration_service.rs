//! Concurrency suite for the `cfp serve` subsystem (ISSUE 4):
//!
//! * N threads submitting the identical request get bit-identical plans
//!   from exactly ONE underlying search (coalescing counter == N − 1,
//!   made deterministic by the leader-hold hook).
//! * Mixed distinct concurrent requests complete and every payload is
//!   byte-identical to the serial one-shot reference through the same
//!   options builder — the CLI/server bit-identity acceptance bar.
//! * TCP loopback round-trip (ephemeral port), including plan-cache
//!   hits across connections and the `stats` request type.
//! * Malformed NDJSON yields a structured error response on every line,
//!   never a crash, and never reaches the planner.

use std::io::{BufRead, BufReader, Write};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use cfp::coordinator::{run_cfp, run_cfp_two_level, CfpOptions, PlannerKind};
use cfp::service::{pipeline_payload, plan_payload, PlanService, RequestKind, ServeConfig};
use cfp::util::cli::Args;
use cfp::util::Json;

fn plan_line(layers: usize) -> String {
    format!(
        "{{\"id\": \"L{layers}\", \"type\": \"plan\", \"model\": \"gpt-tiny\", \
         \"layers\": {layers}, \"platform\": \"a100-pcie\"}}"
    )
}

/// The serial one-shot reference for `plan_line(layers)`: the same
/// fields through the same [`CfpOptions::from_args`] builder, planned by
/// the plain (non-serving) entry point.
fn reference_payload(layers: usize) -> String {
    let mut args = Args::default();
    args.options.insert("model".into(), "gpt-tiny".into());
    args.options.insert("layers".into(), layers.to_string());
    args.options.insert("platform".into(), "a100-pcie".into());
    let built = CfpOptions::from_args(&args, PlannerKind::SingleLevel).unwrap();
    assert!(built.warnings.is_empty());
    plan_payload(&run_cfp(&built.opts)).to_string()
}

fn result_of(resp: &str) -> String {
    let j = Json::parse(resp).expect("response is valid JSON");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "not ok: {resp}");
    j.get("result").expect("ok response has a result").to_string()
}

#[test]
fn n_identical_concurrent_requests_run_exactly_one_search() {
    const N: usize = 6;
    let svc = PlanService::new(ServeConfig { workers: N, ..ServeConfig::default() });
    // Hold the single-flight leader until all N − 1 followers have
    // registered on its flight, so the coalescing count is exact rather
    // than timing-dependent.
    let probe = svc.clone();
    svc.set_search_hook(Arc::new(move || {
        while probe.stats().coalesced < (N as u64) - 1 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }));
    let start = Arc::new(Barrier::new(N));
    let responses: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|_| {
                let svc = svc.clone();
                let start = Arc::clone(&start);
                s.spawn(move || {
                    start.wait();
                    svc.handle_line(&plan_line(2))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let stats = svc.stats();
    assert_eq!(stats.searches, 1, "exactly one underlying search");
    assert_eq!(stats.plan_misses, 1);
    assert_eq!(stats.coalesced, N as u64 - 1, "every other request coalesced");
    assert_eq!(stats.requests, N as u64);

    // all N payloads are bit-identical, and identical to the one-shot
    // CLI path for the same options
    let payloads: Vec<String> = responses.iter().map(|r| result_of(r)).collect();
    for p in &payloads[1..] {
        assert_eq!(p, &payloads[0], "coalesced responses must be bit-identical");
    }
    assert_eq!(payloads[0], reference_payload(2), "served == one-shot CLI plan");

    // cache tags: one miss, N − 1 coalesced
    let mut tags: Vec<String> = responses
        .iter()
        .map(|r| {
            Json::parse(r).unwrap().get("cache").unwrap().as_str().unwrap().to_string()
        })
        .collect();
    tags.sort();
    assert_eq!(tags.iter().filter(|t| *t == "miss").count(), 1);
    assert_eq!(tags.iter().filter(|t| *t == "coalesced").count(), N - 1);
}

#[test]
fn mixed_distinct_concurrent_requests_match_the_serial_reference() {
    let svc = PlanService::new(ServeConfig { workers: 3, ..ServeConfig::default() });
    let layer_counts = [2usize, 3, 4];
    let responses: Vec<(usize, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = layer_counts
            .iter()
            .map(|&layers| {
                let svc = svc.clone();
                s.spawn(move || (layers, svc.handle_line(&plan_line(layers))))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(svc.stats().searches, 3, "distinct requests never coalesce");
    for (layers, resp) in responses {
        assert_eq!(
            result_of(&resp),
            reference_payload(layers),
            "concurrent execution must not change the {layers}-layer plan"
        );
    }
    // profile traffic flowed through the shared cache
    let stats = svc.stats();
    assert!(stats.profile_hits + stats.profile_misses > 0);
}

#[test]
fn served_pipeline_plan_is_bit_identical_to_the_cli_path() {
    let svc = PlanService::new(ServeConfig { workers: 2, ..ServeConfig::default() });
    let line = "{\"type\": \"pipeline\", \"model\": \"gpt-tiny\", \"layers\": 2, \
                \"microbatches\": 4, \"platform\": \"a100-pcie\"}";
    let resp = svc.handle_line(line);

    let mut args = Args::default();
    args.options.insert("model".into(), "gpt-tiny".into());
    args.options.insert("layers".into(), "2".into());
    args.options.insert("microbatches".into(), "4".into());
    args.options.insert("platform".into(), "a100-pcie".into());
    let built = CfpOptions::from_args(&args, PlannerKind::TwoLevel).unwrap();
    let reference = pipeline_payload(&run_cfp_two_level(&built.opts)).to_string();
    assert_eq!(result_of(&resp), reference, "pipeline kind: served == CLI");

    // and a repeat is a plan-cache hit with the same bytes
    let again = svc.handle_line(line);
    assert_eq!(result_of(&again), reference);
    assert_eq!(Json::parse(&again).unwrap().get("cache").and_then(Json::as_str), Some("hit"));
}

#[test]
fn tcp_loopback_round_trip() {
    let svc = PlanService::new(ServeConfig { workers: 2, ..ServeConfig::default() });
    let addr = svc.listen("127.0.0.1:0").expect("bind an ephemeral loopback port");

    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    writeln!(stream, "{}", plan_line(2)).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).expect("valid response JSON");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(j.get("id").and_then(Json::as_str), Some("L2"), "id echoed");
    assert_eq!(j.get("cache").and_then(Json::as_str), Some("miss"));

    // a second connection is served by the same warm service
    let mut stream2 = std::net::TcpStream::connect(addr).expect("connect again");
    let mut reader2 = BufReader::new(stream2.try_clone().expect("clone"));
    writeln!(stream2, "{}", plan_line(2)).unwrap();
    let mut line2 = String::new();
    reader2.read_line(&mut line2).unwrap();
    let j2 = Json::parse(line2.trim()).unwrap();
    assert_eq!(j2.get("cache").and_then(Json::as_str), Some("hit"));
    assert_eq!(
        j2.get("result").unwrap().to_string(),
        j.get("result").unwrap().to_string(),
        "plan served over TCP is byte-stable across connections"
    );

    // stats round-trip over the wire
    writeln!(stream2, "{{\"type\": \"stats\", \"id\": 99}}").unwrap();
    let mut line3 = String::new();
    reader2.read_line(&mut line3).unwrap();
    let j3 = Json::parse(line3.trim()).unwrap();
    assert_eq!(j3.get("kind").and_then(Json::as_str), Some("stats"));
    let r = j3.get("result").unwrap();
    assert_eq!(r.get("searches").and_then(Json::as_u64), Some(1));
    assert_eq!(r.get("plan_hits").and_then(Json::as_u64), Some(1));
}

#[test]
fn malformed_ndjson_yields_structured_errors_never_a_crash() {
    let svc = PlanService::new(ServeConfig { workers: 1, ..ServeConfig::default() });
    let bad_lines = [
        "{not json",
        "[1, 2, 3]",
        "\"a bare string\"",
        "{\"type\": \"frobnicate\"}",
        "{\"model\": \"no-such-model\"}",
        "{\"platform\": \"no-such-platform\"}",
        "{\"modle\": \"gpt-tiny\"}",
        "{\"layers\": \"four\"}",
        "{\"threads\": 8}",
        "{\"type\": \"pipeline\", \"model\": \"gpt-tiny\", \"microbatches\": 0}",
        "{\"type\": \"pipeline\", \"model\": \"gpt-tiny\", \"stages\": \"7\"}",
    ];
    for bad in bad_lines {
        let resp = svc.handle_line(bad);
        let j = Json::parse(&resp)
            .unwrap_or_else(|e| panic!("non-JSON response to {bad:?}: {e}"));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{bad:?}");
        assert!(
            !j.get("error").and_then(Json::as_str).unwrap_or("").is_empty(),
            "{bad:?} must carry an error message"
        );
    }
    let stats = svc.stats();
    assert_eq!(stats.errors, bad_lines.len() as u64);
    assert_eq!(stats.searches, 0, "malformed requests never reach the planner");

    // the service still works afterwards
    let ok = svc.handle_line(&plan_line(2));
    assert_eq!(
        Json::parse(&ok).unwrap().get("ok").and_then(Json::as_bool),
        Some(true),
        "service survives a malformed-input barrage"
    );
}

#[test]
fn requests_are_answered_out_of_order_but_match_by_id() {
    // one stream carrying a slow (cold) and a fast (malformed) request:
    // both answers arrive, each under its own id
    let svc = PlanService::new(ServeConfig { workers: 2, ..ServeConfig::default() });
    let input = format!("{}\n{{\"id\": \"bad\", \"nope\": 1}}\n", plan_line(2));
    struct Sink(Arc<std::sync::Mutex<Vec<u8>>>);
    impl Write for Sink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }
    let buf = Arc::new(std::sync::Mutex::new(Vec::new()));
    svc.serve_stream(
        std::io::Cursor::new(input),
        cfp::service::shared_writer(Sink(Arc::clone(&buf))),
    );
    let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    let mut seen = std::collections::BTreeMap::new();
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        let id = j.get("id").unwrap().as_str().unwrap().to_string();
        seen.insert(id, j.get("ok").and_then(Json::as_bool).unwrap());
    }
    assert_eq!(seen.get("L2"), Some(&true));
    assert_eq!(seen.get("bad"), Some(&false));
}

#[test]
fn request_kinds_expose_their_wire_names() {
    // tiny glue assertions the wire format documentation relies on
    assert_eq!(RequestKind::Plan.as_str(), "plan");
    assert_eq!(RequestKind::Pipeline.as_str(), "pipeline");
    assert_eq!(RequestKind::Stats.as_str(), "stats");
}
