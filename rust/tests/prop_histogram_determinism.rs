//! Property suite: telemetry histogram determinism (PR 7).
//!
//! The serving tier's latency histograms are assembled from per-thread
//! recordings merged in whatever order threads finish — so `merge` must
//! be associative, commutative, and bit-stable, and `bucket_of` must be
//! a pure function of the value (boundaries cannot drift with thread
//! count). Seeded via `Prop::fuzz`: a failure prints the derived seed
//! and `CFP_PROP_SEED=<seed>` replays exactly that case.

use cfp::service::telemetry::{Histogram, HIST_BUCKETS};
use cfp::util::prng::Pcg64;
use cfp::util::proptest::Prop;

/// Latency values biased toward bucket boundaries: zeros, tiny values,
/// exact powers of two, `2^k - 1` / `2^k + 1`, full-range randoms, and
/// near-`u64::MAX` tails.
fn value(rng: &mut Pcg64) -> u64 {
    match rng.below(7) {
        0 => 0,
        1 => rng.below(4),
        2 => 1u64 << rng.below(63),
        3 => (1u64 << (1 + rng.below(62))) - 1,
        4 => (1u64 << (1 + rng.below(62))) + 1,
        5 => rng.next_u64(),
        _ => u64::MAX - rng.below(3),
    }
}

fn record_all(vals: &[u64]) -> Histogram {
    let mut h = Histogram::new();
    for &v in vals {
        h.record(v);
    }
    h
}

#[test]
fn prop_merge_is_associative_commutative_and_equals_sequential() {
    Prop::fuzz(48, 0xA157_9E37).check("histogram_merge_determinism", |rng| {
        let n = 1 + rng.below(200) as usize;
        let vals: Vec<u64> = (0..n).map(|_| value(rng)).collect();
        let whole = record_all(&vals);

        // k-way partition by index: forward and reverse merge orders
        // both reproduce the sequential histogram bit-for-bit
        let k = 2 + rng.below(6) as usize;
        let shards: Vec<Histogram> = (0..k)
            .map(|s| {
                let mine: Vec<u64> =
                    vals.iter().copied().skip(s).step_by(k).collect();
                record_all(&mine)
            })
            .collect();
        let mut fwd = Histogram::new();
        for s in &shards {
            fwd.merge(s);
        }
        let mut rev = Histogram::new();
        for s in shards.iter().rev() {
            rev.merge(s);
        }
        assert_eq!(fwd, whole, "forward shard merge == sequential recording");
        assert_eq!(rev, whole, "merge order must not matter");

        // associativity: (a ∪ b) ∪ c == a ∪ (b ∪ c) on a 3-way split
        if shards.len() >= 3 {
            let (a, b, c) = (&shards[0], &shards[1], &shards[2]);
            let mut left = a.clone();
            left.merge(b);
            left.merge(c);
            let mut bc = b.clone();
            bc.merge(c);
            let mut right = a.clone();
            right.merge(&bc);
            assert_eq!(left, right, "merge is associative");
        }

        // quantiles are a pure function of the (identical) buckets
        for q in [0.5, 0.9, 0.99, 1.0] {
            assert_eq!(fwd.quantile(q), whole.quantile(q));
        }
        assert_eq!(fwd.count(), n as u64);
        assert_eq!(fwd.max_us(), vals.iter().copied().max().unwrap_or(0));
    });
}

#[test]
fn prop_bucket_boundaries_are_stable_pure_functions() {
    Prop::fuzz(64, 0xB0C4E7).check("histogram_bucket_boundaries", |rng| {
        let v = value(rng);
        let b = Histogram::bucket_of(v);
        assert!(b < HIST_BUCKETS);
        // pure: the same value always lands in the same bucket
        assert_eq!(b, Histogram::bucket_of(v));
        // bucket i covers [2^(i-1), 2^i): its bound is its last member
        if (1..HIST_BUCKETS - 1).contains(&b) {
            let bound = Histogram::bucket_bound(b);
            assert!(v <= bound, "{v} exceeds its bucket bound {bound}");
            assert_eq!(Histogram::bucket_of(bound), b);
            assert_eq!(Histogram::bucket_of(bound + 1), b + 1);
        }
        // quantiles are monotone in q
        let n = 1 + rng.below(64) as usize;
        let h = record_all(&(0..n).map(|_| value(rng)).collect::<Vec<_>>());
        let mut prev = 0u64;
        for q in [0.01, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let x = h.quantile(q);
            assert!(x >= prev, "quantile must be monotone in q");
            prev = x;
        }
        assert!(prev <= h.max_us(), "no quantile exceeds the true max");
    });
}

#[test]
fn prop_real_thread_shards_merge_bit_identically() {
    Prop::fuzz(24, 0x7A0D_5EED).check("histogram_thread_shards", |rng| {
        let n = 1 + rng.below(400) as usize;
        let vals: Vec<u64> = (0..n).map(|_| value(rng)).collect();
        let whole = record_all(&vals);
        let threads = 2 + rng.below(5) as usize;

        let shards: Vec<Histogram> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let mine: Vec<u64> =
                        vals.iter().copied().skip(t).step_by(threads).collect();
                    s.spawn(move || record_all(&mine))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut merged = Histogram::new();
        for s in &shards {
            merged.merge(s);
        }
        assert_eq!(
            merged, whole,
            "histogram from {threads} real threads must be bit-identical to sequential"
        );
        assert_eq!(merged.sum_us(), whole.sum_us());
    });
}
