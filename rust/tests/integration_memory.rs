//! Integration tests for the memory subsystem (PR 3): the 1F1B
//! activation-memory accounting and the checkpointing planner.
//!
//! * A cap just below the tightest keep-everything plan is (1) rejected
//!   without checkpointing and (2) recovered — strictly slower but valid
//!   — with `--recompute auto`.
//! * The closed-form per-stage peak matches the event-driven
//!   `cluster::simulate_pipeline_memory` high-water mark **exactly** on
//!   every eval preset (CFP and naive plans alike).
//! * With no `--mem-cap` and `--recompute off`, planning takes the PR 2
//!   code path: deterministic, never recomputing, and a loose-cap
//!   memory-aware run reproduces the same optimum step time.

use cfp::cluster::{simulate_pipeline_memory, Platform, StageMemSpec};
use cfp::coordinator::{run_cfp_two_level, CfpOptions};
use cfp::cost;
use cfp::harness::pipeline_eval_models;
use cfp::interop::{
    exact_crosscheck_stages, plan_pipeline, PipelineOptions, PipelinePlan, StageContexts,
    StageSpec,
};
use cfp::memory::{self, RecomputeSpec};
use cfp::models::{build_training, ModelCfg};
use cfp::profiler::{CacheHandle, ProfileDb, SegmentConfig, SegmentProfile};
use cfp::segment::{SegmentInstance, SegmentSet, UniqueSegment};
use cfp::spmd::{Mesh, ShardState};

/// Cross-check one composed plan: the closed-form 1F1B peak of every
/// stage must equal the event simulation's live-memory high-water mark,
/// bit for bit (both divide whole-batch bytes by the same `m_eff`).
fn check_closed_form_against_sim(plan: &PipelinePlan, tag: &str) {
    let m_eff = plan.memory_microbatches();
    let m = m_eff as u64;
    let lats: Vec<f64> = plan.stages.iter().map(|s| s.latency_us).collect();
    let mems: Vec<StageMemSpec> = plan
        .stages
        .iter()
        .map(|s| StageMemSpec {
            static_bytes: s.footprint.static_bytes,
            retained_per_mb: s.footprint.retained_bytes / m,
            transient_per_mb: s.footprint.transient_bytes / m,
        })
        .collect();
    let high = simulate_pipeline_memory(&lats, m_eff, &mems);
    for (i, st) in plan.stages.iter().enumerate() {
        assert_eq!(high[i], st.peak_mem_bytes, "{tag} stage {i}: sim vs closed form");
    }
    let max_stage = plan.stages.iter().map(|s| s.peak_mem_bytes).max().unwrap();
    assert_eq!(plan.peak_mem_bytes, max_stage, "{tag}: plan peak is the stage max");
}

#[test]
fn tight_cap_rejects_then_recompute_recovers() {
    // search-only harness: profile the stage contexts once, then replan
    // under many caps (bisection) without re-profiling
    let g = build_training(&ModelCfg::preset("gpt-tiny").with_layers(4));
    let popts = PipelineOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    let mut ctxs = StageContexts::new();
    ctxs.ensure_all(&g, &popts, CacheHandle::None);

    let plan_with = |cap: u64, rec: RecomputeSpec| -> Option<PipelinePlan> {
        let mut p = popts.clone();
        p.mem_cap = Some(cap);
        p.recompute = rec;
        plan_pipeline(&g, &ctxs, &p)
    };

    // unconstrained optimum (memory-aware with a boundless cap)
    let best = plan_with(u64::MAX, RecomputeSpec::Off).expect("boundless cap is feasible");
    assert!(best.peak_mem_bytes > 0);

    // bisect the keep-everything feasibility threshold
    let mut lo = 0u64; // infeasible
    let mut hi = best.peak_mem_bytes.saturating_mul(2).max(1); // feasible
    assert!(plan_with(lo, RecomputeSpec::Off).is_none(), "cap 0 must reject");
    assert!(plan_with(hi, RecomputeSpec::Off).is_some());
    // converge to ~0.1% below the threshold — close enough that the
    // checkpointed recovery is comfortably feasible, in ~11 replans
    let tol = best.peak_mem_bytes / 1024 + 1;
    while hi - lo > tol {
        let mid = lo + (hi - lo) / 2;
        if plan_with(mid, RecomputeSpec::Off).is_some() {
            hi = mid;
        } else {
            lo = mid;
        }
    }

    // (1) the tightened cap is rejected without checkpointing...
    assert!(plan_with(lo, RecomputeSpec::Off).is_none(), "rejected without recompute");
    // (2) ...and recovered as a strictly slower but valid plan with auto
    let rec = plan_with(lo, RecomputeSpec::Auto)
        .expect("recompute must recover a plan just below the keep-everything threshold");
    assert!(rec.peak_mem_bytes <= lo, "recovered plan respects the cap");
    assert!(
        rec.step_time_us > best.step_time_us,
        "recompute is never free: {} vs unconstrained {}",
        rec.step_time_us,
        best.step_time_us
    );
    assert!(
        rec.stages.iter().any(|s| s.remat.iter().any(|&x| x)),
        "the recovery actually checkpoints at least one segment"
    );
    check_closed_form_against_sim(&rec, "recovered");

    // monotonicity: a feasible cap never yields a faster plan than a
    // looser one
    let loose = plan_with(hi, RecomputeSpec::Auto).unwrap();
    assert!(loose.step_time_us <= rec.step_time_us + 1e-9 * rec.step_time_us);
}

#[test]
fn cap_exactly_at_a_frontier_peak_is_inclusive_and_exact_certified() {
    let g = build_training(&ModelCfg::preset("gpt-tiny").with_layers(4));
    let popts = PipelineOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    let mut ctxs = StageContexts::new();
    ctxs.ensure_all(&g, &popts, CacheHandle::None);

    let plan_with = |cap: u64| -> (PipelineOptions, Option<PipelinePlan>) {
        let mut p = popts.clone();
        p.mem_cap = Some(cap);
        p.recompute = RecomputeSpec::Auto;
        let plan = plan_pipeline(&g, &ctxs, &p);
        (p, plan)
    };

    let (_, best) = plan_with(u64::MAX);
    let best = best.expect("boundless cap is feasible");

    // a cap EXACTLY equal to the chosen plan's 1F1B peak is inclusive
    // (the feasibility test is ≤, not <): the optimum is unchanged bit
    // for bit, because the boundless winner itself still fits
    let (p_at, at) = plan_with(best.peak_mem_bytes);
    let at = at.expect("cap == peak must stay feasible");
    assert!(
        at.step_time_us.to_bits() == best.step_time_us.to_bits(),
        "cap == peak: {} vs boundless {}",
        at.step_time_us,
        best.step_time_us
    );
    assert!(at.peak_mem_bytes <= best.peak_mem_bytes);
    // the exact lane re-solves every stage span; a worse-than-DP exact
    // time would be a genuine bug (a known DP thinning approximation is
    // reported distinctly and tolerated)
    match exact_crosscheck_stages(&ctxs, &p_at, &at, 64.0) {
        Ok(checked) => assert!(checked > 0, "the exact lane must certify at least one stage"),
        Err(e) => assert!(e.contains("DP suboptimal"), "{e}"),
    }

    // one byte below that peak, the chosen plan no longer fits: whatever
    // replaces it (if anything) is slower-or-equal and respects the cap
    let (p_below, below) = plan_with(best.peak_mem_bytes - 1);
    if let Some(b) = &below {
        assert!(b.peak_mem_bytes < best.peak_mem_bytes, "cap is binding");
        assert!(b.step_time_us >= at.step_time_us, "tightening never speeds up");
        if let Err(e) = exact_crosscheck_stages(&ctxs, &p_below, b, 64.0) {
            assert!(e.contains("DP suboptimal"), "{e}");
        }
    }
}

#[test]
fn cap_below_every_plan_is_an_honest_none_certified_by_the_exact_lane() {
    let g = build_training(&ModelCfg::preset("gpt-tiny").with_layers(2));
    let popts = PipelineOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    let mut ctxs = StageContexts::new();
    ctxs.ensure_all(&g, &popts, CacheHandle::None);
    let mut p = popts.clone();
    p.mem_cap = Some(1);
    p.recompute = RecomputeSpec::Auto;
    assert!(plan_pipeline(&g, &ctxs, &p).is_none(), "a 1-byte cap must reject honestly");

    // certify the rejection: for every candidate stage count, every
    // possible stage-0 span is infeasible at the cap under the COMPLETE
    // searcher, so no split can even start — the None is genuine
    // infeasibility, not an artifact of the DP's frontier thinning
    let total = popts.mesh.total();
    for ctx in ctxs.iter() {
        let k = total / ctx.devices;
        let sctx = cost::SearchCtx::new(&ctx.segments, &ctx.db);
        let n = ctx.segments.instances.len();
        let me = memory::memory_microbatches(k, p.microbatches);
        let f0 = memory::inflight_microbatches(k, 0, me);
        for hi in 1..=n {
            let ex = cost::search_span_mem_exact(&sctx, 0, hi, RecomputeSpec::Auto);
            assert!(
                memory::select_feasible(&ex, me, f0, 1).is_none(),
                "k = {k}: stage-0 span [0,{hi}) must not fit a 1-byte cap"
            );
        }
    }
}

/// A chain of one single-config segment whose checkpoint boundary is
/// tiny next to its kept activation — the planner's only memory lever is
/// *how many* instances to checkpoint, so the frontier is a clean
/// per-count ladder and the checkpoint-everything plan is its min-peak
/// endpoint.
fn one_config_chain(n: usize) -> (SegmentSet, ProfileDb) {
    let mut db = ProfileDb::default();
    db.segments.push(SegmentProfile {
        configs: vec![SegmentConfig { strategy: vec![0] }],
        t_c_us: vec![5.0],
        t_p_us: vec![10.0],
        mem_bytes: vec![8100],
        act_bytes: vec![8000],
        ckpt_bytes: vec![8],
        t_fwd_us: vec![4.0],
        symbolic_volume: vec![0],
        boundary_out: vec![ShardState::Replicated],
        boundary_in: vec![ShardState::Replicated],
    });
    let instances = (0..n)
        .map(|_| SegmentInstance { unique_id: 0, blocks: vec![], fwd_range: (0, 0) })
        .collect();
    let unique = vec![UniqueSegment { id: 0, fingerprint: "u0".into(), rep: 0, count: n }];
    (SegmentSet { instances, unique }, db)
}

#[test]
fn checkpoint_everything_boundary_matches_the_exact_lane() {
    let n = 4;
    let (ss, db) = one_config_chain(n);
    let sctx = cost::SearchCtx::new(&ss, &db);
    let dp = cost::search_span_mem(&ss, &db, 0, n, RecomputeSpec::Auto);
    let ex = cost::search_span_mem_exact(&sctx, 0, n, RecomputeSpec::Auto);
    // ≤ n + 1 distinct checkpoint counts — far below the DP's frontier
    // caps, so the production frontier must equal the exact one bit for
    // bit (duplicate remat placements collapse identically; the
    // checkpoint COUNT is pinned by the time, since t_fwd > 0)
    assert_eq!(dp.len(), ex.len(), "frontier sizes");
    assert!(dp.len() == n + 1, "one point per checkpoint count");
    for (a, b) in dp.iter().zip(&ex) {
        assert!(a.time_us.to_bits() == b.time_us.to_bits());
        assert_eq!(a.footprint.static_bytes, b.footprint.static_bytes);
        assert_eq!(a.footprint.retained_bytes, b.footprint.retained_bytes);
        assert_eq!(a.footprint.transient_bytes, b.footprint.transient_bytes);
        let (ka, kb) = (
            a.remat.iter().filter(|&&r| r).count(),
            b.remat.iter().filter(|&&r| r).count(),
        );
        assert_eq!(ka, kb, "checkpoint counts");
    }
    let (me, f) = (8, 4);
    let peaks: Vec<u64> = ex.iter().map(|p| p.peak_bytes(me, f)).collect();
    let min_peak = *peaks.iter().min().unwrap();
    // cap EXACTLY the checkpoint-everything peak: inclusive, and both
    // searchers select the identical all-checkpoint plan
    let d = memory::select_feasible(&dp, me, f, min_peak).expect("cap == min peak fits");
    let e = memory::select_feasible(&ex, me, f, min_peak).expect("cap == min peak fits");
    assert!(d.time_us.to_bits() == e.time_us.to_bits());
    assert!(e.remat.iter().all(|&r| r), "the tightest cap checkpoints everything");
    assert_eq!(e.peak_bytes(me, f), min_peak);
    // one byte below it: honest None through both lanes
    assert!(memory::select_feasible(&dp, me, f, min_peak - 1).is_none());
    assert!(memory::select_feasible(&ex, me, f, min_peak - 1).is_none());
    // boundless: both heads are the keep-everything plan
    let d = memory::select_feasible(&dp, me, f, u64::MAX).unwrap();
    let e = memory::select_feasible(&ex, me, f, u64::MAX).unwrap();
    assert!(d.time_us.to_bits() == e.time_us.to_bits());
    assert!(e.remat.iter().all(|&r| !r), "a boundless cap never recomputes");
}

#[test]
fn closed_form_peak_matches_event_simulation_on_eval_presets() {
    for model in pipeline_eval_models() {
        let mut opts = CfpOptions::new(model.clone(), Platform::a100_pcie(4).scaled_testbed())
            .with_stages(StageSpec::Auto)
            .with_microbatches(8)
            .with_recompute(RecomputeSpec::Auto);
        opts.mesh = Mesh::flat(4);
        let r = run_cfp_two_level(&opts);
        let p = r.pipeline.expect("eval presets fit the device capacity");
        check_closed_form_against_sim(&p, &model.name);
        if let Some(n) = r.naive.as_ref() {
            check_closed_form_against_sim(n, &format!("{} (naive)", model.name));
        }
    }
    // the two-node testbed exercises deeper stage counts
    let gpt = pipeline_eval_models().remove(0);
    let mut opts = CfpOptions::new(gpt.clone(), Platform::a100_two_node().scaled_testbed())
        .with_stages(StageSpec::Auto)
        .with_microbatches(8)
        .with_recompute(RecomputeSpec::Auto);
    opts.mesh = Mesh { intra: 8, nodes: 2 };
    let r = run_cfp_two_level(&opts);
    let p = r.pipeline.expect("2-node gpt fits");
    check_closed_form_against_sim(&p, "gpt@2node");
    if let Some(n) = r.naive.as_ref() {
        check_closed_form_against_sim(n, "gpt@2node (naive)");
    }
}

#[test]
fn legacy_mode_keeps_pr2_semantics() {
    let opts = |rec: RecomputeSpec, cap: Option<u64>| {
        let mut o = CfpOptions::new(
            ModelCfg::preset("gpt-tiny").with_layers(3),
            Platform::a100_pcie(4),
        )
        .with_stages(StageSpec::Auto)
        .with_recompute(rec);
        o.mem_cap = cap;
        o
    };

    // deterministic and recompute-free with the flags unset/off
    let a = run_cfp_two_level(&opts(RecomputeSpec::Off, None));
    let b = run_cfp_two_level(&opts(RecomputeSpec::Off, None));
    let (pa, pb) = (a.pipeline.unwrap(), b.pipeline.unwrap());
    assert_eq!(pa.num_stages(), pb.num_stages());
    assert!(pa.step_time_us == pb.step_time_us, "bit-identical across runs");
    assert_eq!(pa.mem_bytes, pb.mem_bytes);
    for (x, y) in pa.stages.iter().zip(&pb.stages) {
        assert_eq!(x.plan.choice, y.plan.choice);
        assert!(x.remat.iter().all(|&r| !r), "legacy mode never recomputes");
    }
    // the accounting is still reported: peaks cover at least the static
    // footprint and the plan peak is the stage max
    check_closed_form_against_sim(&pa, "legacy");
    for st in &pa.stages {
        assert!(st.peak_mem_bytes >= st.footprint.static_bytes);
    }

    // a loose-cap memory-aware run reproduces the same optimum step time
    // (the memory axis only ever removes infeasible plans, it does not
    // perturb the time objective)
    let c = run_cfp_two_level(&opts(RecomputeSpec::Auto, Some(u64::MAX)));
    let pc = c.pipeline.unwrap();
    assert!(
        (pc.step_time_us - pa.step_time_us).abs() <= 1e-9 * pa.step_time_us.max(1.0),
        "loose cap: {} vs legacy {}",
        pc.step_time_us,
        pa.step_time_us
    );
}
