//! Property suite for the PR 9 observability layer: the determinism
//! contract of [`cfp::obs::Trace`] counters and the `cfp explain`
//! rendering, plus the zero-perturbation guarantee of tracing itself.
//!
//! Randomized over small built-in presets (chain and SP-DAG), engines
//! (DP and auto), and thread counts:
//!
//! * **counter determinism** — the full counter snapshot after a
//!   traced `run_cfp` is identical across `threads = 1` and
//!   `threads = 4`. Counters are additive sums flushed from
//!   deterministic work partitions, so the schedule must not show.
//! * **explain determinism** — `render_explain` output is
//!   byte-identical across thread counts (it quotes only plan numbers,
//!   profile tables, counters and notes — never wall-clock).
//! * **no perturbation** — running with an enabled trace yields the
//!   bit-identical plan (choice, time bits, memory) of an untraced run.
//! * **trace file well-formedness** — `write_chrome` emits JSON that
//!   the crate's own pure-std parser accepts, with a non-empty
//!   `traceEvents` array and the Chrome trace-event envelope.
//!
//! Failures replay with `CFP_PROP_SEED=<printed value>`.

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::cost::SearchEngine;
use cfp::models::ModelCfg;
use cfp::obs::{explain, Trace};
use cfp::util::proptest::Prop as Harness;
use cfp::util::Json;

/// One randomized planner setup: preset × layers × engine.
fn random_opts(rng: &mut cfp::util::Pcg64) -> CfpOptions {
    let (preset, layers) = match rng.below(3) {
        0 => ("gpt-tiny", 2),
        1 => ("gpt-tiny", 3),
        _ => ("moe-ep-tiny", 2),
    };
    let engine = if rng.below(2) == 0 { SearchEngine::Dp } else { SearchEngine::Auto };
    CfpOptions::new(ModelCfg::preset(preset).with_layers(layers), Platform::a100_pcie(4))
        .with_engine(engine)
}

#[test]
fn prop_counters_and_explain_identical_across_threads() {
    Harness::fuzz(20, 0x0B5E5).check("obs determinism across thread counts", |rng| {
        let base = random_opts(rng);
        let mut runs = Vec::new();
        for threads in [1usize, 4] {
            let mut opts = base.clone().with_trace(Trace::enabled());
            opts.threads = threads;
            let r = run_cfp(&opts);
            let snapshot = opts.trace.snapshot();
            let text = explain::render_explain(&r, &opts);
            runs.push((r, snapshot, text));
        }
        let (r1, snap1, text1) = &runs[0];
        let (r4, snap4, text4) = &runs[1];
        assert_eq!(snap1, snap4, "counter snapshot differs across thread counts");
        assert_eq!(text1, text4, "explain text differs across thread counts");
        assert!(
            r1.plan.time_us.to_bits() == r4.plan.time_us.to_bits()
                && r1.plan.choice == r4.plan.choice,
            "plan differs across thread counts"
        );
        // the traced counters actually observed the search
        assert!(
            snap1.iter().any(|&(k, v)| k == "segment_instances" && v > 0),
            "segment_instances never counted: {snap1:?}"
        );
    });
}

#[test]
fn prop_tracing_never_changes_the_plan() {
    Harness::fuzz(20, 0x70FF).check("trace on/off plan identity", |rng| {
        let base = random_opts(rng);
        let traced = base.clone().with_trace(Trace::enabled());
        let off = run_cfp(&base);
        let on = run_cfp(&traced);
        assert!(
            off.plan.time_us.to_bits() == on.plan.time_us.to_bits()
                && off.plan.choice == on.plan.choice
                && off.plan.mem_bytes == on.plan.mem_bytes,
            "tracing perturbed the plan: {} vs {}",
            off.plan.time_us,
            on.plan.time_us
        );
        assert!(
            base.trace.snapshot().iter().all(|&(_, v)| v == 0),
            "disabled trace accumulated counters"
        );
    });
}

#[test]
fn chrome_trace_file_is_well_formed_json() {
    let opts = CfpOptions::new(ModelCfg::preset("gpt-tiny").with_layers(2), Platform::a100_pcie(4))
        .with_trace(Trace::enabled());
    let _ = run_cfp(&opts);
    let path = std::env::temp_dir().join(format!("cfp_trace_{}.json", std::process::id()));
    opts.trace.write_chrome(&path).expect("trace file written");
    let text = std::fs::read_to_string(&path).expect("trace file readable");
    let _ = std::fs::remove_file(&path);
    let j = Json::parse(&text).expect("trace file parses as JSON");
    assert_eq!(
        j.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms"),
        "chrome trace envelope"
    );
    let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "trace has no events");
    for e in events {
        assert!(e.get("name").and_then(Json::as_str).is_some(), "event without name: {e:?}");
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"), "non-complete event");
    }
    // the counter event carries every counter the run incremented
    let counters = events
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("cfp.counters"))
        .expect("cfp.counters event");
    let args = counters.get("args").expect("counter args");
    assert!(args.get("segment_instances").and_then(Json::as_u64).unwrap_or(0) > 0);
}
