//! Property tests over randomized models/configs (DESIGN.md §6 invariants)
//! using the in-repo seeded property harness.

use cfp::affine::{propagate, Prop};
use cfp::cluster::Platform;
use cfp::cost;
use cfp::graph::Role;
use cfp::models::{build_training, Arch, ModelCfg};
use cfp::pblock::{build_parallel_blocks, Sharding};
use cfp::profiler::{profile_model, ProfileOptions};
use cfp::segment::extract_segments;
use cfp::spmd::{lower, GlobalPlan, Mesh};
use cfp::util::proptest::Prop as Harness;
use cfp::util::Pcg64;

fn random_model(rng: &mut Pcg64) -> ModelCfg {
    let arch = *rng.choice(&[Arch::Gpt, Arch::Llama, Arch::Moe, Arch::Bert]);
    let heads = *rng.choice(&[2usize, 4]);
    let hidden = heads * *rng.choice(&[8usize, 16]);
    let mut cfg = ModelCfg::preset(match arch {
        Arch::Gpt => "gpt-tiny",
        Arch::Moe => "moe-tiny",
        _ => "gpt-tiny",
    });
    cfg.arch = arch;
    cfg.hidden = hidden;
    cfg.heads = heads;
    cfg.ffn = hidden * 2;
    cfg.layers = 1 + rng.below(3) as usize;
    cfg.seq = *rng.choice(&[16usize, 32]);
    cfg.batch = *rng.choice(&[4usize, 8]);
    cfg.vocab = 256;
    cfg.experts = 4;
    cfg.dropout = rng.below(2) == 0;
    cfg
}

/// Invariant 2/3: inside every block, every strategy propagates
/// communication-free and assigns consistent shardings.
#[test]
fn prop_blocks_are_communication_free() {
    Harness::fuzz(24, 0xB10C).check("pblock soundness", |rng| {
        let cfg = random_model(rng);
        let parts = *rng.choice(&[2usize, 4]);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, parts);
        for blk in &bs.blocks {
            for st in &blk.strategies {
                for &m in &blk.ops {
                    if m == blk.entry {
                        continue;
                    }
                    for (idx, inp) in g.ops[m].inputs.iter().enumerate() {
                        if let Some(Sharding::Split(d)) = st.assignment.get(inp) {
                            match propagate(&g, m, idx, *d, parts) {
                                Prop::To { out_dim, .. } => assert_eq!(
                                    st.assignment.get(&m),
                                    Some(&Sharding::Split(out_dim)),
                                    "{}: inconsistent assignment",
                                    g.ops[m].name
                                ),
                                Prop::Blocked => panic!(
                                    "blocked inside block at {} ({} strat {})",
                                    g.ops[m].name, blk.id, st.label
                                ),
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Invariant: DP lowering never em its activation collectives beyond RNG-free
/// grad sync; and per-device flops always ≤ serial flops.
#[test]
fn prop_lowering_flops_bounded() {
    Harness::fuzz(16, 0xF10). check("lowering flops", |rng| {
        let cfg = random_model(rng);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let serial = g.total_flops();
        for label in ["m", "n", "k"] {
            if let Some(plan) = GlobalPlan::uniform(&bs, label, Mesh::flat(4)) {
                let prog = lower(&g, &bs, &plan);
                let dev = prog.total_flops();
                assert!(dev <= serial + serial / 8, "{label}: {dev} > serial {serial}");
                assert!(dev * 5 >= serial, "{label}: suspiciously little work");
            }
        }
    });
}

/// Invariant 6: the Pareto DP equals brute force on random small instances
/// under random memory caps.
#[test]
fn prop_search_optimal_vs_brute_force() {
    Harness::fuzz(10, 0x5EA2C4).check("search optimality", |rng| {
        let mut cfg = random_model(rng);
        cfg.layers = 1 + rng.below(2) as usize; // keep brute force sane
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 2);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(2));
        let db = profile_model(&g, &bs, &ss, &opts);
        // skip pathologically large spaces
        let space: f64 = ss
            .instances
            .iter()
            .map(|i| db.segments[i.unique_id].configs.len() as f64)
            .product();
        if space > 25_000.0 {
            return;
        }
        let free = cost::search(&ss, &db, None).unwrap();
        let caps = [None, Some(free.mem_bytes), Some((free.mem_bytes as f64 * 0.9) as u64)];
        for cap in caps {
            let dp = cost::search(&ss, &db, cap);
            let bf = cost::brute_force(&ss, &db, cap);
            match (dp, bf) {
                (Some(d), Some(b)) => assert!(
                    d.time_us <= b.time_us * 1.02 + 1e-6,
                    "cap {cap:?}: dp {} bf {}",
                    d.time_us,
                    b.time_us
                ),
                (None, None) => {}
                (d, b) => panic!("feasibility mismatch: {d:?} vs {b:?}"),
            }
        }
    });
}

/// Invariant 4: fingerprint-equal segments have identical config spaces and
/// (by construction) identical profiles.
#[test]
fn prop_fingerprint_equal_segments_share_space() {
    Harness::fuzz(16, 0xF1D6E).check("fingerprint soundness", |rng| {
        let cfg = random_model(rng);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 2);
        let ss = extract_segments(&g, &bs);
        for u in &ss.unique {
            let insts: Vec<_> = ss
                .instances
                .iter()
                .filter(|i| i.unique_id == u.id)
                .collect();
            for w in insts.windows(2) {
                assert_eq!(w[0].blocks.len(), w[1].blocks.len(), "block counts differ");
                for (&a, &b) in w[0].blocks.iter().zip(&w[1].blocks) {
                    assert_eq!(
                        bs.blocks[a].strategies.len(),
                        bs.blocks[b].strategies.len(),
                        "strategy spaces differ within fingerprint"
                    );
                }
            }
        }
    });
}

/// Backward ops always land in their forward op's block (§3.2).
#[test]
fn prop_bwd_ops_follow_fwd_blocks() {
    Harness::fuzz(16, 0xB3D).check("bwd grouping", |rng| {
        let cfg = random_model(rng);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 2);
        for op in &g.ops {
            if op.role == Role::Bwd {
                if let Some(f) = op.grad_of {
                    if let Some(b) = bs.block_of[f] {
                        assert_eq!(bs.block_of[op.id], Some(b), "{} strayed", op.name);
                    }
                }
            }
        }
    });
}
