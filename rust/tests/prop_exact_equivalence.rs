//! Differential property suite for the PR 6 exact lane: the production
//! DP searchers vs `cost::exact`'s branch-and-bound / full-Pareto
//! enumeration — an oracle that shares **no pruning assumptions** with
//! the DP (unlike `cost::oracle`, the verbatim pre-refactor copy of the
//! same algorithm).
//!
//! Randomized instances stay small (≤ 12 instances × ≤ 4 configs) so
//! exhaustive enumeration is cheap, and include absent reshard tables
//! (the dense-matrix 0.0 default) and single-config uniques. Three
//! lanes:
//!
//! * **unconstrained scalar** — DP optimum == exact optimum bit-for-bit
//!   on any instance (float `+` of a constant is monotone, so the DP's
//!   min over left-associated path sums is the true min).
//! * **capped** — generator pins every unique's config memories to
//!   `base_u` or `base_u + delta` with one *shared* delta, so a span of
//!   length L has ≤ L + 1 distinct prefix memory sums, the per-state
//!   Pareto set stays under `FRONTIER_CAP`, thinning provably never
//!   engages — and the DP must therefore be bit-identical to exact.
//! * **memory frontier** — the DP's min-time head must match the exact
//!   head bit-for-bit (the head survives every DP prune), every DP point
//!   must be dominated-or-equal by an exact point, and the feasibility
//!   selection over the exact frontier must never lose to the DP's.
//!
//! Plus three adversarial cases: a dense-frontier chain where the DP's
//! `FRONTIER_CAP` thinning engages and the exact lane is validated
//! against a closed-form count enumeration instead; a hand-built
//! instance of `prune_mem`'s real blind spot (a non-dominated frontier
//! point dropped by the running-min rule — `cost::oracle` shares the
//! rule verbatim and misses it, the exact Pareto set catches it); and
//! an injected pre-fork tie-break perturbation that a DP-vs-oracle
//! differential cannot see but the exact lane refutes.

use cfp::cost::{self, oracle};
use cfp::memory::{self, RecomputeSpec};
use cfp::profiler::{ProfileDb, ReshardTable, SegmentConfig, SegmentProfile};
use cfp::segment::{SegmentInstance, SegmentSet, UniqueSegment};
use cfp::spmd::ShardState;
use cfp::util::proptest::Prop as Harness;
use cfp::util::Pcg64;

/// Per-config memory draw: unconstrained random bytes, or the two-value
/// `base + {0, delta}` family the capped lane needs (see module doc).
enum MemModel {
    Free,
    TwoValued { delta: u64 },
}

fn random_profile(rng: &mut Pcg64, cfgs: usize, mem: &MemModel) -> SegmentProfile {
    let base = 500 + rng.below(4000);
    let mem_bytes: Vec<u64> = (0..cfgs)
        .map(|_| match mem {
            MemModel::Free => 500 + rng.below(4000),
            MemModel::TwoValued { delta } => base + rng.below(2) * delta,
        })
        .collect();
    let act_bytes: Vec<u64> = mem_bytes.iter().map(|&m| rng.below(m + 1)).collect();
    let ckpt_bytes: Vec<u64> = act_bytes.iter().map(|&a| rng.below(a + 1)).collect();
    SegmentProfile {
        configs: (0..cfgs).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
        t_c_us: (0..cfgs).map(|_| rng.f64() * 200.0).collect(),
        t_p_us: (0..cfgs).map(|_| rng.f64() * 400.0).collect(),
        mem_bytes,
        act_bytes,
        ckpt_bytes,
        t_fwd_us: (0..cfgs).map(|_| rng.f64() * 100.0).collect(),
        symbolic_volume: vec![0; cfgs],
        boundary_out: vec![ShardState::Replicated; cfgs],
        boundary_in: vec![ShardState::Replicated; cfgs],
    }
}

/// A small random `(SegmentSet, ProfileDb)`: ≤ 12 instances, ≤ 4 configs
/// per unique (single-config uniques included), reshard tables absent
/// for ~1/3 of the adjacent pairs.
fn random_small_setup(rng: &mut Pcg64, mem: MemModel) -> (SegmentSet, ProfileDb) {
    let uniques = 1 + rng.below(3) as usize;
    let mut db = ProfileDb::default();
    for _ in 0..uniques {
        let cfgs = 1 + rng.below(4) as usize;
        db.segments.push(random_profile(rng, cfgs, &mem));
    }
    for a in 0..uniques {
        for b in 0..uniques {
            if rng.below(3) > 0 {
                let (ca, cb) = (db.segments[a].configs.len(), db.segments[b].configs.len());
                let t_r_us: Vec<Vec<f64>> =
                    (0..ca).map(|_| (0..cb).map(|_| rng.f64() * 50.0).collect()).collect();
                db.reshard.insert(
                    (a, b),
                    ReshardTable { t_r_us, sym_vol: vec![vec![0; cb]; ca], programs: ca * cb },
                );
            }
        }
    }
    let n = 3 + rng.below(10) as usize; // 3..=12
    let uids: Vec<usize> = (0..n).map(|_| rng.below(uniques as u64) as usize).collect();
    let instances: Vec<SegmentInstance> = uids
        .iter()
        .map(|&u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
        .collect();
    let unique: Vec<UniqueSegment> = (0..uniques)
        .map(|u| UniqueSegment {
            id: u,
            fingerprint: format!("u{u}"),
            rep: uids.iter().position(|&x| x == u).unwrap_or(0),
            count: uids.iter().filter(|&&x| x == u).count(),
        })
        .collect();
    (SegmentSet { instances, unique }, db)
}

fn random_span(rng: &mut Pcg64, n: usize) -> (usize, usize) {
    let lo = rng.below(n as u64) as usize;
    let hi = lo + 1 + rng.below((n - lo) as u64) as usize;
    (lo, hi)
}

fn assert_times_eq(a: &Option<cost::Plan>, b: &Option<cost::Plan>, what: &str) {
    match (a, b) {
        (Some(a), Some(b)) => {
            assert!(
                a.time_us.to_bits() == b.time_us.to_bits(),
                "{what}: time {} vs {}",
                a.time_us,
                b.time_us
            );
        }
        (None, None) => {}
        _ => panic!("{what}: feasibility mismatch {a:?} vs {b:?}"),
    }
}

#[test]
fn prop_unconstrained_dp_cost_equals_exact_optimum() {
    Harness::fuzz(500, 0xE5AC7).check("unconstrained DP ≡ exact optimum", |rng| {
        let (ss, db) = random_small_setup(rng, MemModel::Free);
        let ctx = cost::SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        let mut spans = vec![(0, n)];
        for _ in 0..2 {
            spans.push(random_span(rng, n));
        }
        for (lo, hi) in spans {
            let dp = cost::search_span_ctx(&ctx, None, lo, hi);
            let ex = cost::search_span_exact(&ctx, None, lo, hi);
            assert_times_eq(&dp, &ex, &format!("[{lo},{hi})"));
        }
    });
}

#[test]
fn prop_capped_dp_cost_equals_exact_optimum() {
    Harness::fuzz(500, 0xCA99ED).check("capped DP ≡ exact optimum", |rng| {
        let delta = 1 + rng.below(2000);
        let (ss, db) = random_small_setup(rng, MemModel::TwoValued { delta });
        let ctx = cost::SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        let free = cost::search_span_ctx(&ctx, None, 0, n).expect("uncapped is feasible");
        let caps = [
            1u64,
            free.mem_bytes.saturating_sub(delta),
            free.mem_bytes.saturating_sub(1),
            free.mem_bytes,
            free.mem_bytes + rng.below(4 * delta + 1),
        ];
        let mut spans = vec![(0, n)];
        spans.push(random_span(rng, n));
        for (lo, hi) in spans {
            for cap in caps {
                let dp = cost::search_span_ctx(&ctx, Some(cap), lo, hi);
                let ex = cost::search_span_exact(&ctx, Some(cap), lo, hi);
                assert_times_eq(&dp, &ex, &format!("[{lo},{hi}) cap {cap}"));
                if let Some(e) = &ex {
                    assert!(e.mem_bytes <= cap, "[{lo},{hi}) cap {cap}: exact plan fits");
                }
            }
        }
    });
}

#[test]
fn prop_mem_frontier_head_matches_and_exact_dominates() {
    Harness::fuzz(500, 0x3F207E).check("mem frontier: head ≡, exact dominates", |rng| {
        let (ss, db) = random_small_setup(rng, MemModel::Free);
        let ctx = cost::SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        let spec = if rng.below(2) == 0 { RecomputeSpec::Off } else { RecomputeSpec::Auto };
        for (lo, hi) in [(0, n), random_span(rng, n)] {
            let dp = cost::search_span_mem_ctx(&ctx, lo, hi, spec);
            let ex = cost::search_span_mem_exact(&ctx, lo, hi, spec);
            assert!(!dp.is_empty() && !ex.is_empty(), "[{lo},{hi}) {spec:?}");

            // the min-time head survives every DP prune, and with
            // continuous random times the optimal path is unique — so
            // the whole head point must agree bit-for-bit
            let (dh, eh) = (&dp[0], &ex[0]);
            assert!(
                dh.time_us.to_bits() == eh.time_us.to_bits(),
                "[{lo},{hi}) {spec:?}: head {} vs {}",
                dh.time_us,
                eh.time_us
            );
            assert_eq!(dh.choice, eh.choice, "[{lo},{hi}) {spec:?}: head choice");
            assert_eq!(dh.remat, eh.remat, "[{lo},{hi}) {spec:?}: head remat");
            assert_eq!(dh.footprint.static_bytes, eh.footprint.static_bytes);
            assert_eq!(dh.footprint.retained_bytes, eh.footprint.retained_bytes);
            assert_eq!(dh.footprint.transient_bytes, eh.footprint.transient_bytes);
            assert!(
                dh.footprint.recompute_us.to_bits() == eh.footprint.recompute_us.to_bits()
            );

            // completeness: whatever the DP kept, the exact Pareto set
            // covers (dominance over time + all footprint components)
            for p in &dp {
                assert!(
                    ex.iter().any(|q| q.time_us <= p.time_us
                        && q.footprint.static_bytes <= p.footprint.static_bytes
                        && q.footprint.retained_bytes <= p.footprint.retained_bytes
                        && q.footprint.transient_bytes <= p.footprint.transient_bytes),
                    "[{lo},{hi}) {spec:?}: DP point t={} not covered",
                    p.time_us
                );
            }

            // the feasibility selection over the exact frontier never
            // loses to the DP frontier's, at any cap the DP can realize
            let me = 1 + rng.below(8) as usize;
            let f = 1 + rng.below(4) as usize;
            let caps: Vec<u64> =
                dp.iter().map(|p| p.peak_bytes(me, f)).chain([0, u64::MAX]).collect();
            for cap in caps {
                let from_dp = memory::select_feasible(&dp, me, f, cap).map(|p| p.time_us);
                let from_ex = memory::select_feasible(&ex, me, f, cap).map(|p| p.time_us);
                match (from_dp, from_ex) {
                    (Some(d), Some(e)) => {
                        assert!(e <= d, "cap {cap}: exact selection {e} worse than DP {d}")
                    }
                    // exact may be feasible where the thinned DP is not —
                    // that is the DP's documented approximation...
                    (None, Some(_)) => {}
                    // ...but never the other way around
                    (Some(d), None) => {
                        panic!("cap {cap}: DP feasible at {d} but exact claims infeasible")
                    }
                    (None, None) => {}
                }
            }
            // and a boundless cap selects the bit-identical head on both
            let d = memory::select_feasible(&dp, me, f, u64::MAX).unwrap();
            let e = memory::select_feasible(&ex, me, f, u64::MAX).unwrap();
            assert!(d.time_us.to_bits() == e.time_us.to_bits());
        }
    });
}

/// The chain that defeats `FRONTIER_CAP` thinning: one unique with four
/// configs whose times are `4, 3+ε, 2+3ε, 1+7ε` (ε = 2⁻¹⁰, all dyadic —
/// every sum exact) and memories `1, 2, 3, 4`, no reshard. A length-L
/// prefix has 3L+1 distinct memory sums, each Pareto-optimal (the base
/// time is an exact linear function of memory and the nonlinear ε
/// weights `0,1,3,7` break every cross-count tie), so by position 9 the
/// per-state frontier exceeds 24 points and the DP must thin real
/// frontier points away.
fn thinning_chain() -> (SegmentSet, ProfileDb) {
    let eps = 2f64.powi(-10);
    let weights = [0.0, 1.0, 3.0, 7.0];
    let mut db = ProfileDb::default();
    db.segments.push(SegmentProfile {
        configs: (0..4).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
        t_c_us: (0..4).map(|c| (4 - c) as f64 + weights[c] * eps).collect(),
        t_p_us: vec![0.0; 4],
        mem_bytes: (1..=4).collect(),
        act_bytes: vec![0; 4],
        ckpt_bytes: vec![0; 4],
        t_fwd_us: vec![0.0; 4],
        symbolic_volume: vec![0; 4],
        boundary_out: vec![ShardState::Replicated; 4],
        boundary_in: vec![ShardState::Replicated; 4],
    });
    let n = 10;
    let instances: Vec<SegmentInstance> = (0..n)
        .map(|_| SegmentInstance { unique_id: 0, blocks: vec![], fwd_range: (0, 0) })
        .collect();
    let unique = vec![UniqueSegment { id: 0, fingerprint: "u0".into(), rep: 0, count: n }];
    (SegmentSet { instances, unique }, db)
}

/// Independent mini-oracle for [`thinning_chain`]: with no reshard and
/// one unique, a plan is just a config-count vector — enumerate all
/// `n1 + n2 + n3 ≤ 10` triples and take the exact closed-form optimum.
fn thinning_chain_optimum(cap: u64) -> Option<f64> {
    let eps = 2f64.powi(-10);
    let n = 10i64;
    let mut best: Option<f64> = None;
    for n1 in 0..=n {
        for n2 in 0..=(n - n1) {
            for n3 in 0..=(n - n1 - n2) {
                let n0 = n - n1 - n2 - n3;
                let mem = (n0 + 2 * n1 + 3 * n2 + 4 * n3) as u64;
                if mem > cap {
                    continue;
                }
                let time = (4 * n0 + 3 * n1 + 2 * n2 + n3) as f64
                    + (n1 + 3 * n2 + 7 * n3) as f64 * eps;
                if best.map_or(true, |b| time < b) {
                    best = Some(time);
                }
            }
        }
    }
    best
}

#[test]
fn exact_matches_closed_form_on_dense_frontier_chain() {
    // the per-state frontier here exceeds FRONTIER_CAP from position 9
    // on, so the DP runs its thinning path; the exact lane is validated
    // bit-for-bit against a *closed-form* count enumeration instead (an
    // oracle that shares no code with either searcher), and the DP and
    // `cost::oracle` stay locked together whatever thinning does
    let (ss, db) = thinning_chain();
    let ctx = cost::SearchCtx::new(&ss, &db);
    let n = ss.instances.len();
    for cap in 10..=40u64 {
        let dp = cost::search_span_ctx(&ctx, Some(cap), 0, n).expect("cap ≥ min mem");
        let orc = oracle::search_span_reference(&ss, &db, Some(cap), 0, n).expect("feasible");
        let ex = cost::search_span_exact(&ctx, Some(cap), 0, n).expect("feasible");
        let want = thinning_chain_optimum(cap).expect("cap ≥ min mem");
        assert!(
            dp.time_us.to_bits() == orc.time_us.to_bits(),
            "cap {cap}: oracle and DP must agree bit-for-bit (shared algorithm)"
        );
        assert!(
            ex.time_us.to_bits() == want.to_bits(),
            "cap {cap}: exact {} vs closed form {}",
            ex.time_us,
            want
        );
        assert!(ex.mem_bytes <= cap, "cap {cap}: exact plan fits");
        assert!(ex.time_us <= dp.time_us, "cap {cap}: exact never worse than the DP");
    }
}

/// The *real* (not injected) shared blind spot of the DP and its
/// verbatim oracle copy: `prune_mem` keeps a point only when it lowers
/// the running minimum of some footprint component in time order —
/// which can drop a point **no kept point dominates**. Two positions
/// suffice: u0's three configs produce, inside u1's single state, the
/// time-ordered footprints (stat, ret) = (5, 1), (1, 5), (2, 2). The
/// third lowers no running minimum (both are already 1) and is pruned,
/// yet nothing dominates it — and at `m_eff = inflight = 1` its peak
/// `2 + 2 + 0 = 4` beats the survivors' `6`, so under a cap of 4 or 5
/// the DP (and the oracle, bit-for-bit) answer "infeasible" while the
/// exact Pareto set still holds the feasible plan.
#[test]
fn mem_prune_blind_spot_caught_by_exact_but_invisible_to_oracle() {
    let mut db = ProfileDb::default();
    // u0: three configs, times 1/2/3, (stat, ret) = (5,1), (1,5), (2,2)
    db.segments.push(SegmentProfile {
        configs: (0..3).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
        t_c_us: vec![1.0, 2.0, 3.0],
        t_p_us: vec![0.0; 3],
        mem_bytes: vec![6, 6, 4],
        act_bytes: vec![1, 5, 2],
        ckpt_bytes: vec![0; 3],
        t_fwd_us: vec![0.0; 3],
        symbolic_volume: vec![0; 3],
        boundary_out: vec![ShardState::Replicated; 3],
        boundary_in: vec![ShardState::Replicated; 3],
    });
    // u1: a single weightless config — merely funnels all three paths
    // into one state so prune_mem sees them together
    db.segments.push(SegmentProfile {
        configs: vec![SegmentConfig { strategy: vec![0] }],
        t_c_us: vec![1.0],
        t_p_us: vec![0.0],
        mem_bytes: vec![0],
        act_bytes: vec![0],
        ckpt_bytes: vec![0],
        t_fwd_us: vec![0.0],
        symbolic_volume: vec![0],
        boundary_out: vec![ShardState::Replicated],
        boundary_in: vec![ShardState::Replicated],
    });
    let instances: Vec<SegmentInstance> = [0usize, 1]
        .iter()
        .map(|&u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
        .collect();
    let unique: Vec<UniqueSegment> = (0..2)
        .map(|u| UniqueSegment { id: u, fingerprint: format!("u{u}"), rep: u, count: 1 })
        .collect();
    let ss = SegmentSet { instances, unique };
    let ctx = cost::SearchCtx::new(&ss, &db);
    let spec = RecomputeSpec::Off;

    let dp = cost::search_span_mem_ctx(&ctx, 0, 2, spec);
    let orc = oracle::search_span_mem_reference(&ss, &db, 0, 2, spec);
    let ex = cost::search_span_mem_exact(&ctx, 0, 2, spec);

    // the oracle shares prune_mem verbatim: identical frontier — the
    // existing differential suite cannot see the dropped point
    assert_eq!(dp.len(), orc.len(), "DP and oracle frontiers line up");
    for (a, b) in dp.iter().zip(&orc) {
        assert!(a.time_us.to_bits() == b.time_us.to_bits());
        assert_eq!(a.footprint.static_bytes, b.footprint.static_bytes);
        assert_eq!(a.footprint.retained_bytes, b.footprint.retained_bytes);
        assert_eq!(a.footprint.transient_bytes, b.footprint.transient_bytes);
    }

    // the DP kept 2 of the 3 non-dominated points; exact keeps all 3
    assert_eq!(dp.len(), 2, "prune_mem drops the non-dominated middle point");
    assert_eq!(ex.len(), 3, "the exact Pareto set keeps it");
    assert!(ex
        .iter()
        .any(|p| p.footprint.static_bytes == 2 && p.footprint.retained_bytes == 2));

    // under caps 4 and 5 the dropped point is the only feasible plan:
    // DP and oracle claim infeasible, exact proves feasible
    for cap in [4u64, 5] {
        assert!(
            memory::select_feasible(&dp, 1, 1, cap).is_none(),
            "cap {cap}: the DP frontier has no feasible point"
        );
        assert!(
            memory::select_feasible(&orc, 1, 1, cap).is_none(),
            "cap {cap}: the oracle shares the blind spot"
        );
        let found = memory::select_feasible(&ex, 1, 1, cap)
            .expect("the exact frontier still holds the feasible plan");
        assert!(found.time_us.to_bits() == 4.0f64.to_bits());
        assert_eq!(found.choice, vec![2, 0], "the pruned config-2 path");
    }
    // with a loose cap all three agree on the min-time head
    let d = memory::select_feasible(&dp, 1, 1, u64::MAX).unwrap();
    let e = memory::select_feasible(&ex, 1, 1, u64::MAX).unwrap();
    assert!(d.time_us.to_bits() == e.time_us.to_bits());
    assert!(d.time_us.to_bits() == 2.0f64.to_bits());
}

/// A capped DP with a deliberately perturbed tie-break, standing in for
/// a bug introduced *before* `cost::oracle` was forked: among time-equal
/// states it keeps the largest-memory point instead of the smallest.
/// Chain positions are single-unique-free (no reshard), so the DP is
/// just per-position (time, mem) frontier propagation.
fn perturbed_capped_dp(times: &[Vec<f64>], mems: &[Vec<u64>], cap: u64) -> Option<f64> {
    let mut states: Vec<(f64, u64)> = vec![(0.0, 0)];
    for (ts, ms) in times.iter().zip(mems) {
        let mut next: Vec<(f64, u64)> = Vec::new();
        for &(t, m) in &states {
            for (c, &ct) in ts.iter().enumerate() {
                let (nt, nm) = (t + ct, m + ms[c]);
                if nm <= cap {
                    next.push((nt, nm));
                }
            }
        }
        // the injected perturbation: sort (time asc, mem DESC) and keep
        // the first point per distinct time value — i.e. the tie-break
        // keeps the memory-hungriest of time-equal states
        next.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(b.1.cmp(&a.1)));
        next.dedup_by(|a, b| a.0 == b.0);
        states = next;
        if states.is_empty() {
            return None;
        }
    }
    states.iter().map(|&(t, _)| t).min_by(|a, b| a.partial_cmp(b).unwrap())
}

#[test]
fn injected_tie_break_perturbation_caught_only_by_exact() {
    // A has two configs with *identical* total time 1.0 (0.5+0.5 and
    // 0.25+0.75 — dyadic, exactly equal) but memories 4 vs 2; B and C
    // are single-config (time 1.0, mem 1); no reshard tables. Cap 5.
    let mut db = ProfileDb::default();
    let profile = |t_c: Vec<f64>, t_p: Vec<f64>, mem: Vec<u64>| {
        let k = mem.len();
        SegmentProfile {
            configs: (0..k).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
            t_c_us: t_c,
            t_p_us: t_p,
            mem_bytes: mem,
            act_bytes: vec![0; k],
            ckpt_bytes: vec![0; k],
            t_fwd_us: vec![0.0; k],
            symbolic_volume: vec![0; k],
            boundary_out: vec![ShardState::Replicated; k],
            boundary_in: vec![ShardState::Replicated; k],
        }
    };
    db.segments.push(profile(vec![0.5, 0.25], vec![0.5, 0.75], vec![4, 2]));
    db.segments.push(profile(vec![0.5], vec![0.5], vec![1]));
    db.segments.push(profile(vec![0.5], vec![0.5], vec![1]));
    let instances: Vec<SegmentInstance> = (0..3)
        .map(|u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
        .collect();
    let unique: Vec<UniqueSegment> = (0..3)
        .map(|u| UniqueSegment { id: u, fingerprint: format!("u{u}"), rep: u, count: 1 })
        .collect();
    let ss = SegmentSet { instances, unique };
    let ctx = cost::SearchCtx::new(&ss, &db);
    let cap = 5u64;

    // sanity: production DP, pre-refactor oracle and the exact lane all
    // find the plan (A's lean config + B + C = time 3.0, mem 4 ≤ 5)
    let dp = cost::search_span_ctx(&ctx, Some(cap), 0, 3).expect("production DP solves this");
    let orc = oracle::search_span_reference(&ss, &db, Some(cap), 0, 3).expect("oracle too");
    let ex = cost::search_span_exact(&ctx, Some(cap), 0, 3).expect("exact too");
    assert!(dp.time_us.to_bits() == 3.0f64.to_bits());
    assert!(orc.time_us.to_bits() == 3.0f64.to_bits());
    assert!(ex.time_us.to_bits() == 3.0f64.to_bits());
    assert_eq!(ex.mem_bytes, 4);

    // the perturbed tie-break keeps A's fat config, dead-ends at C —
    // and because the bug predates the production/oracle fork, BOTH
    // copies return the same wrong answer: the differential suite passes
    let times = vec![vec![1.0, 1.0], vec![1.0], vec![1.0]];
    let mems = vec![vec![4, 2], vec![1], vec![1]];
    let perturbed_production = perturbed_capped_dp(&times, &mems, cap);
    let perturbed_oracle = perturbed_capped_dp(&times, &mems, cap);
    assert_eq!(
        perturbed_production, perturbed_oracle,
        "DP-vs-oracle differential is blind to a pre-fork perturbation"
    );
    assert_eq!(perturbed_production, None, "the perturbation loses the feasible plan");

    // only an oracle that does not share the tie-break — the exact
    // lane — flags the perturbed result as wrong
    assert_ne!(perturbed_production, Some(ex.time_us));
    assert!(
        perturbed_production.is_none() && cost::search_span_exact(&ctx, Some(cap), 0, 3).is_some(),
        "exact refutes the perturbed infeasibility verdict"
    );
}
