//! Property suite for the PR 5 repetition-aware search core: randomized
//! profiles × random memory caps × random span bounds, asserting the
//! collapsed / sweep-based searchers return plans **bit-identical**
//! (choice, time, mem — floats compared by bits) to the pre-refactor DP
//! kept verbatim in `cfp::cost::oracle`.
//!
//! The synthetic generator builds chains with *runs* of repeated uniques
//! (the steady-state splice's trigger), leaves some reshard tables
//! absent (the dense matrices must reproduce the 0.0 default), and
//! includes degenerate shapes (single-config uniques, single-instance
//! spans).

use cfp::cost::{self, oracle};
use cfp::memory::{self, RecomputeSpec};
use cfp::profiler::{ProfileDb, ReshardTable, SegmentConfig, SegmentProfile};
use cfp::segment::{SegmentInstance, SegmentSet, UniqueSegment};
use cfp::spmd::ShardState;
use cfp::util::proptest::Prop as Harness;
use cfp::util::Pcg64;

fn random_profile(rng: &mut Pcg64, cfgs: usize) -> SegmentProfile {
    let mem_bytes: Vec<u64> = (0..cfgs).map(|_| 500 + rng.below(4000)).collect();
    let act_bytes: Vec<u64> = mem_bytes.iter().map(|&m| rng.below(m + 1)).collect();
    let ckpt_bytes: Vec<u64> = act_bytes.iter().map(|&a| rng.below(a + 1)).collect();
    SegmentProfile {
        configs: (0..cfgs).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
        t_c_us: (0..cfgs).map(|_| rng.f64() * 200.0).collect(),
        t_p_us: (0..cfgs).map(|_| rng.f64() * 400.0).collect(),
        mem_bytes,
        act_bytes,
        ckpt_bytes,
        t_fwd_us: (0..cfgs).map(|_| rng.f64() * 100.0).collect(),
        symbolic_volume: vec![0; cfgs],
        boundary_out: vec![ShardState::Replicated; cfgs],
        boundary_in: vec![ShardState::Replicated; cfgs],
    }
}

/// A random `(SegmentSet, ProfileDb)` pair. `deep` biases towards long
/// chains with long runs of one unique — the splice's steady state.
fn random_setup(rng: &mut Pcg64, deep: bool) -> (SegmentSet, ProfileDb) {
    let uniques = 1 + rng.below(3) as usize;
    let mut db = ProfileDb::default();
    for _ in 0..uniques {
        let cfgs = 1 + rng.below(4) as usize;
        db.segments.push(random_profile(rng, cfgs));
    }
    // reshard tables for ~2/3 of the pairs; the rest default to 0.0
    for a in 0..uniques {
        for b in 0..uniques {
            if rng.below(3) > 0 {
                let (ca, cb) = (db.segments[a].configs.len(), db.segments[b].configs.len());
                let t_r_us: Vec<Vec<f64>> =
                    (0..ca).map(|_| (0..cb).map(|_| rng.f64() * 50.0).collect()).collect();
                db.reshard.insert(
                    (a, b),
                    ReshardTable { t_r_us, sym_vol: vec![vec![0; cb]; ca], programs: ca * cb },
                );
            }
        }
    }
    let target = if deep { 120 + rng.below(140) as usize } else { 3 + rng.below(18) as usize };
    let max_run = if deep { 60 } else { 6 };
    let mut uids: Vec<usize> = Vec::new();
    while uids.len() < target {
        let u = rng.below(uniques as u64) as usize;
        let run = 1 + rng.below(max_run) as usize;
        for _ in 0..run {
            uids.push(u);
            if uids.len() >= target {
                break;
            }
        }
    }
    let instances: Vec<SegmentInstance> = uids
        .iter()
        .map(|&u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
        .collect();
    let unique: Vec<UniqueSegment> = (0..uniques)
        .map(|u| UniqueSegment {
            id: u,
            fingerprint: format!("u{u}"),
            rep: uids.iter().position(|&x| x == u).unwrap_or(0),
            count: uids.iter().filter(|&&x| x == u).count(),
        })
        .collect();
    (SegmentSet { instances, unique }, db)
}

fn random_span(rng: &mut Pcg64, n: usize) -> (usize, usize) {
    let lo = rng.below(n as u64) as usize;
    let hi = lo + 1 + rng.below((n - lo) as u64) as usize;
    (lo, hi)
}

fn assert_plans_eq(a: &Option<cost::Plan>, b: &Option<cost::Plan>, what: &str) {
    match (a, b) {
        (Some(a), Some(b)) => {
            assert_eq!(a.choice, b.choice, "{what}: choice");
            assert!(
                a.time_us.to_bits() == b.time_us.to_bits(),
                "{what}: time {} vs {}",
                a.time_us,
                b.time_us
            );
            assert_eq!(a.mem_bytes, b.mem_bytes, "{what}: mem");
        }
        (None, None) => {}
        _ => panic!("{what}: feasibility mismatch {a:?} vs {b:?}"),
    }
}

#[test]
fn prop_span_search_bit_identical_to_reference() {
    Harness::fuzz(48, 0x5EA5C4).check("span search ≡ reference", |rng| {
        let (ss, db) = random_setup(rng, false);
        let n = ss.instances.len();
        let free = oracle::search_span_reference(&ss, &db, None, 0, n).expect("always feasible");
        let caps = [
            None,
            Some(1u64),
            Some(rng.below(free.mem_bytes + 1)),
            Some((free.mem_bytes as f64 * 0.8) as u64),
            Some(free.mem_bytes),
        ];
        for _ in 0..6 {
            let (lo, hi) = random_span(rng, n);
            for cap in caps {
                let new = cost::search_span(&ss, &db, cap, lo, hi);
                let reference = oracle::search_span_reference(&ss, &db, cap, lo, hi);
                assert_plans_eq(&new, &reference, &format!("[{lo},{hi}) cap {cap:?}"));
            }
        }
        // and the whole chain
        for cap in caps {
            let new = cost::search_span(&ss, &db, cap, 0, n);
            let reference = oracle::search_span_reference(&ss, &db, cap, 0, n);
            assert_plans_eq(&new, &reference, &format!("[0,{n}) cap {cap:?}"));
        }
    });
}

#[test]
fn prop_deep_repeated_chains_splice_exactly() {
    // long runs of one unique: the steady-state splice must engage and
    // still agree with the per-position reference bit-for-bit
    Harness::fuzz(10, 0xDEEC0DE).check("deep chain splice ≡ reference", |rng| {
        let (ss, db) = random_setup(rng, true);
        let n = ss.instances.len();
        let new = cost::search(&ss, &db, None);
        let reference = oracle::search_span_reference(&ss, &db, None, 0, n);
        assert_plans_eq(&new, &reference, &format!("deep [0,{n})"));
        for _ in 0..3 {
            let (lo, hi) = random_span(rng, n);
            let new = cost::search_span(&ss, &db, None, lo, hi);
            let reference = oracle::search_span_reference(&ss, &db, None, lo, hi);
            assert_plans_eq(&new, &reference, &format!("deep [{lo},{hi})"));
        }
    });
}

#[test]
fn prop_sweep_times_fold_the_reference_retry() {
    Harness::fuzz(24, 0x5EEB).check("sweep ≡ capped-then-unconstrained retry", |rng| {
        let (ss, db) = random_setup(rng, false);
        let n = ss.instances.len();
        let ctx = cost::SearchCtx::new(&ss, &db);
        let free = oracle::search_span_reference(&ss, &db, None, 0, n).expect("feasible");
        for cap in [1u64, free.mem_bytes / 2, free.mem_bytes, u64::MAX] {
            let lo = rng.below(n as u64) as usize;
            let swept = cost::sweep_span_times(&ctx, lo, cap);
            assert_eq!(swept.len(), n - lo);
            for hi in (lo + 1)..=n {
                let want = oracle::search_span_reference(&ss, &db, Some(cap), lo, hi)
                    .or_else(|| oracle::search_span_reference(&ss, &db, None, lo, hi))
                    .map(|p| p.time_us);
                let got = swept[hi - lo - 1];
                match (got, want) {
                    (Some(a), Some(b)) => {
                        assert!(a.to_bits() == b.to_bits(), "[{lo},{hi}) cap {cap}: {a} vs {b}")
                    }
                    (None, None) => {}
                    (a, b) => panic!("[{lo},{hi}) cap {cap}: {a:?} vs {b:?}"),
                }
            }
        }
    });
}

#[test]
fn prop_mem_frontier_bit_identical_to_reference() {
    Harness::fuzz(24, 0x3E3).check("memory frontier ≡ reference", |rng| {
        let (ss, db) = random_setup(rng, false);
        let n = ss.instances.len();
        for spec in [RecomputeSpec::Off, RecomputeSpec::Auto] {
            for _ in 0..4 {
                let (lo, hi) = random_span(rng, n);
                let new = cost::search_span_mem(&ss, &db, lo, hi, spec);
                let reference = oracle::search_span_mem_reference(&ss, &db, lo, hi, spec);
                assert_eq!(new.len(), reference.len(), "[{lo},{hi}) {spec:?}");
                for (a, b) in new.iter().zip(&reference) {
                    assert_eq!(a.choice, b.choice, "[{lo},{hi}) {spec:?}");
                    assert_eq!(a.remat, b.remat, "[{lo},{hi}) {spec:?}");
                    assert!(a.time_us.to_bits() == b.time_us.to_bits(), "[{lo},{hi}) {spec:?}");
                    assert_eq!(a.footprint.static_bytes, b.footprint.static_bytes);
                    assert_eq!(a.footprint.retained_bytes, b.footprint.retained_bytes);
                    assert_eq!(a.footprint.transient_bytes, b.footprint.transient_bytes);
                    assert!(
                        a.footprint.recompute_us.to_bits() == b.footprint.recompute_us.to_bits()
                    );
                }
            }
        }
    });
}

#[test]
fn prop_sweep_frontiers_and_selection_match_reference() {
    Harness::fuzz(16, 0xF207).check("frontier sweep ≡ per-span reference", |rng| {
        let (ss, db) = random_setup(rng, false);
        let n = ss.instances.len();
        let ctx = cost::SearchCtx::new(&ss, &db);
        let spec = if rng.below(2) == 0 { RecomputeSpec::Off } else { RecomputeSpec::Auto };
        let lo = rng.below(n as u64) as usize;
        let swept = cost::sweep_span_frontiers(&ctx, lo, spec);
        for hi in (lo + 1)..=n {
            let reference = oracle::search_span_mem_reference(&ss, &db, lo, hi, spec);
            let rows = &swept[hi - lo - 1];
            assert_eq!(rows.len(), reference.len(), "[{lo},{hi}) {spec:?}");
            for (r, p) in rows.iter().zip(&reference) {
                assert!(r.time_us.to_bits() == p.time_us.to_bits());
                assert_eq!(r.static_bytes, p.footprint.static_bytes);
                assert_eq!(r.retained_bytes, p.footprint.retained_bytes);
                assert_eq!(r.transient_bytes, p.footprint.transient_bytes);
            }
            // the value-only feasibility probe picks the same plan the
            // reconstruction will
            let me = 1 + rng.below(8) as usize;
            let f = 1 + rng.below(4) as usize;
            let caps: Vec<u64> = reference
                .iter()
                .map(|p| p.peak_bytes(me, f))
                .chain([0, u64::MAX])
                .collect();
            for cap in caps {
                let want = memory::select_feasible(&reference, me, f, cap).map(|p| p.time_us);
                let got = cost::select_time(rows, me, f, cap);
                match (got, want) {
                    (Some(a), Some(b)) => {
                        assert!(a.to_bits() == b.to_bits(), "cap {cap}: {a} vs {b}")
                    }
                    (None, None) => {}
                    (a, b) => panic!("cap {cap}: {a:?} vs {b:?}"),
                }
            }
        }
    });
}
