//! Chaos suite (PR 10): seeded deterministic fault schedules drive full
//! serve + plan + pipeline runs through `util::failpoint`, asserting the
//! three invariants that define "survived":
//!
//! 1. the process never dies — every injected panic, torn write, dead
//!    socket, and exhausted budget is absorbed by its domain's recovery
//!    code;
//! 2. every response is either byte-identical to the fault-free plan or
//!    a structured error — never a wrong plan;
//! 3. the admission ledger and telemetry counters reconcile exactly, and
//!    every armed fault site reports a nonzero evaluation count (a
//!    failpoint nothing reaches is a dead failpoint, treated as a bug).
//!
//! The registry is process-global, so every test serializes on one mutex
//! and disarms via RAII. Fault-free references are always computed
//! *inside* the lock, before arming. The flagship schedule's seed comes
//! from `CFP_CHAOS_SEED` (default 1) and the full spec is printed so any
//! CI failure replays locally with `CFP_FAULTS="<spec>"` or `--faults`.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use cfp::coordinator::{run_cfp, CfpOptions, PlannerKind};
use cfp::service::{plan_payload, shared_writer, PlanService, ServeConfig};
use cfp::util::cli::Args;
use cfp::util::{failpoint, Json};

static CHAOS: Mutex<()> = Mutex::new(());

/// Hold the suite lock with everything disarmed (references are computed
/// under this before arming a schedule).
fn chaos_lock() -> MutexGuard<'static, ()> {
    let g = CHAOS.lock().unwrap_or_else(|e| e.into_inner());
    failpoint::disarm_all();
    g
}

/// RAII disarm: a failing assertion must not leak an armed schedule into
/// the next test.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        failpoint::disarm_all();
    }
}

fn arm(spec: &str) -> Disarm {
    println!("chaos schedule (replay via CFP_FAULTS or --faults): {spec}");
    failpoint::arm(spec).expect("chaos spec parses");
    Disarm
}

fn plan_line(id: &str, layers: usize) -> String {
    format!(
        "{{\"id\": \"{id}\", \"type\": \"plan\", \"model\": \"gpt-tiny\", \
         \"layers\": {layers}, \"platform\": \"a100-pcie\"}}"
    )
}

fn engine_line(id: &str, layers: usize, engine: &str) -> String {
    format!(
        "{{\"id\": \"{id}\", \"type\": \"plan\", \"model\": \"gpt-tiny\", \
         \"layers\": {layers}, \"platform\": \"a100-pcie\", \"engine\": \"{engine}\"}}"
    )
}

/// Fault-free one-shot reference: the same fields through the same
/// options builder, planned without the service. MUST be called with the
/// registry disarmed.
fn reference_payload(layers: usize, engine: Option<&str>) -> String {
    assert!(!failpoint::armed(), "references must be fault-free");
    let mut args = Args::default();
    args.options.insert("model".into(), "gpt-tiny".into());
    args.options.insert("layers".into(), layers.to_string());
    args.options.insert("platform".into(), "a100-pcie".into());
    if let Some(e) = engine {
        args.options.insert("engine".into(), e.to_string());
    }
    let built = CfpOptions::from_args(&args, PlannerKind::SingleLevel).unwrap();
    assert!(built.warnings.is_empty());
    plan_payload(&run_cfp(&built.opts)).to_string()
}

fn result_of(resp: &str) -> String {
    let j = Json::parse(resp).expect("response is valid JSON");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "not ok: {resp}");
    j.get("result").expect("ok response has a result").to_string()
}

fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cfp_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// `Write` into a shared buffer (the serve_stream response sink).
struct Sink(Arc<Mutex<Vec<u8>>>);
impl Write for Sink {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap_or_else(|e| e.into_inner()).extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn assert_ledger(svc: &PlanService) {
    let s = svc.stats();
    assert_eq!(
        s.received,
        s.admitted + s.rejected + s.coalesced,
        "admission ledger reconciles"
    );
    assert_eq!(
        s.rejected,
        s.rejected_overload + s.rejected_draining + s.rejected_unauthorized,
        "rejection decomposition reconciles"
    );
    assert_eq!(s.admitted, s.plan_hits + s.plan_misses, "admitted decomposition reconciles");
}

/// The flagship: one seeded schedule arming every cache-I/O and serving
/// fault at once, driven through the full `serve_stream` stack (reader
/// thread, worker pool, shared writer) from four concurrent streams over
/// persistent caches seeded beforehand.
#[test]
fn seeded_schedule_full_stack_survives_serves_right_or_errs_and_reconciles() {
    let _g = chaos_lock();
    let seed: u64 = std::env::var("CFP_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    const LAYERS: std::ops::RangeInclusive<usize> = 2..=6;
    const THREADS: usize = 4;
    const ROUNDS: usize = 3;

    // fault-free references, computed disarmed
    let refs: BTreeMap<usize, String> =
        LAYERS.map(|l| (l, reference_payload(l, None))).collect();

    // seed both persistent caches so load-time sites have bytes to corrupt
    let dir = scratch("flagship");
    let cfg = || ServeConfig {
        workers: THREADS,
        cache_path: Some(dir.join("profiles.json")),
        plan_cache_file: Some(dir.join("plans.json")),
        ..ServeConfig::default()
    };
    {
        let svc = PlanService::new(cfg());
        for l in LAYERS {
            let resp = svc.handle_line(&plan_line(&format!("seed{l}"), l));
            assert_eq!(result_of(&resp), refs[&l], "seeding run is fault-free");
        }
        svc.drain();
    }

    let spec = format!(
        "profile_cache.load_corrupt:once,\
         profile_cache.torn_save:first=1,\
         profile_cache.lock_timeout:every=2,\
         profile_cache.miss_storm:p=0.3@{seed},\
         plan_cache.torn_save:first=1,\
         plan_cache.version_skew:once,\
         search.panic:every=5,\
         serve.worker_panic:every=7,\
         serve.frame_corrupt:every=9"
    );
    let _d = arm(&spec);

    let svc = PlanService::new(cfg());
    let buffers: Vec<Arc<Mutex<Vec<u8>>>> =
        (0..THREADS).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
    std::thread::scope(|s| {
        for (t, buf) in buffers.iter().enumerate() {
            let svc = svc.clone();
            let buf = Arc::clone(buf);
            s.spawn(move || {
                let input: String = (0..ROUNDS)
                    .flat_map(|r| {
                        LAYERS.map(move |l| plan_line(&format!("L{l}x{t}x{r}"), l) + "\n")
                    })
                    .collect();
                svc.serve_stream(std::io::Cursor::new(input), shared_writer(Sink(buf)));
            });
        }
    });

    // invariant 1 held by arriving here; invariant 2 per response line
    let total_lines = THREADS * ROUNDS * LAYERS.count();
    let (mut ok, mut errs) = (0usize, 0usize);
    for buf in &buffers {
        let text =
            String::from_utf8(buf.lock().unwrap_or_else(|e| e.into_inner()).clone()).unwrap();
        for resp in text.lines() {
            let j = Json::parse(resp)
                .unwrap_or_else(|e| panic!("unparseable response {resp:?}: {e}"));
            match j.get("ok").and_then(Json::as_bool) {
                Some(true) => {
                    ok += 1;
                    let id = j.get("id").and_then(Json::as_str).expect("ok echoes id");
                    let layers: usize =
                        id[1..id.find('x').expect("chaos id shape")].parse().unwrap();
                    assert_eq!(
                        j.get("result").expect("ok has result").to_string(),
                        refs[&layers],
                        "WRONG PLAN under faults for layers={layers}"
                    );
                }
                Some(false) => {
                    errs += 1;
                    assert!(
                        j.get("error").is_some() || j.get("reason").is_some(),
                        "unstructured failure: {resp}"
                    );
                }
                None => panic!("response without ok: {resp}"),
            }
        }
    }
    assert_eq!(ok + errs, total_lines, "every line is answered exactly once");
    assert!(ok > 0, "some requests must succeed under this schedule");
    assert!(errs > 0, "this schedule provably injected failures");

    // invariant 3: ledger reconciles and every line is accounted for
    assert_ledger(&svc);
    assert_eq!(svc.stats().requests, total_lines as u64);

    // no dead failpoints: every armed site was reached...
    let all_sites = [
        "profile_cache.load_corrupt",
        "profile_cache.torn_save",
        "profile_cache.lock_timeout",
        "profile_cache.miss_storm",
        "plan_cache.torn_save",
        "plan_cache.version_skew",
        "search.panic",
        "serve.worker_panic",
        "serve.frame_corrupt",
    ];
    for site in all_sites {
        assert!(failpoint::eval_count(site) > 0, "dead failpoint (never evaluated): {site}");
    }
    // ...and the deterministic (non-probabilistic) schedules all fired
    for site in all_sites {
        if site != "profile_cache.miss_storm" {
            assert!(failpoint::trip_count(site) > 0, "armed site never tripped: {site}");
        }
    }
    // the obs audit surface sees the same registry
    assert_eq!(cfp::obs::fault_counters().len(), all_sites.len());

    // an armed `stats` response exposes the per-site audit
    let stats_resp = svc.handle_line("{\"id\": \"st\", \"type\": \"stats\"}");
    let sj = Json::parse(&stats_resp).unwrap();
    let faults = sj.get("result").and_then(|r| r.get("faults")).cloned();
    assert!(faults.is_some(), "armed stats responses carry the fault audit: {stats_resp}");

    svc.drain();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Worker-panic isolation, counted exactly: the first two pool jobs die
/// inside the injected panic; both come back as structured
/// `internal_error` responses, the rest serve the fault-free bytes, and
/// the ledger never saw the panicked requests.
#[test]
fn worker_panics_are_isolated_and_counted_exactly() {
    let _g = chaos_lock();
    let reference = reference_payload(2, None);
    let _d = arm("serve.worker_panic:first=2");

    let svc = PlanService::new(ServeConfig { workers: 2, ..ServeConfig::default() });
    let input: String = (0..5).map(|i| plan_line(&format!("w{i}"), 2) + "\n").collect();
    let buf = Arc::new(Mutex::new(Vec::new()));
    svc.serve_stream(std::io::Cursor::new(input), shared_writer(Sink(Arc::clone(&buf))));

    let text = String::from_utf8(buf.lock().unwrap_or_else(|e| e.into_inner()).clone()).unwrap();
    let (mut ok, mut internal) = (0, 0);
    for resp in text.lines() {
        let j = Json::parse(resp).expect("worker panic still yields a JSON line");
        if j.get("ok").and_then(Json::as_bool) == Some(true) {
            ok += 1;
            assert_eq!(j.get("result").unwrap().to_string(), reference);
        } else {
            internal += 1;
            let msg = j.get("error").and_then(Json::as_str).unwrap_or_default().to_string();
            assert!(
                msg.contains("internal_error") && msg.contains("serve.worker_panic"),
                "structured internal_error names the injected fault: {resp}"
            );
            assert!(j.get("id").is_some(), "internal errors still echo the id: {resp}");
        }
    }
    assert_eq!((ok, internal), (3, 2), "exactly the first two jobs died: {text}");
    assert_eq!(failpoint::trip_count("serve.worker_panic"), 2);

    let s = svc.stats();
    assert_eq!(s.requests, 5, "every line accounted, including the panicked ones");
    assert_eq!(s.received, 3, "panicked requests never reached admission");
    assert_eq!(s.errors, 2);
    assert_ledger(&svc);
    svc.drain();
}

/// TCP transport: an injected accept failure drops one connection (the
/// client sees EOF, not a hang), a torn response write reaches the
/// client as a malformed frame on a stream that keeps working, a wedged
/// peer is cut loose by the read deadline, and the daemon stays fully
/// alive throughout.
#[test]
fn tcp_lane_survives_accept_failure_torn_writes_and_dead_clients() {
    let _g = chaos_lock();
    let reference = reference_payload(2, None);
    let svc = PlanService::new(ServeConfig {
        workers: 2,
        read_timeout: Some(Duration::from_millis(250)),
        write_timeout: Some(Duration::from_secs(5)),
        ..ServeConfig::default()
    });
    let addr = svc.listen("127.0.0.1:0").expect("ephemeral bind");
    let _d = arm("serve.accept_fail:once,serve.write_torn:once");

    // connection 1 is dropped by the accept-failure fault: EOF, no hang
    {
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let _ = writeln!(c, "{}", plan_line("a1", 2));
        let mut resp = String::new();
        let n = BufReader::new(c.try_clone().unwrap()).read_line(&mut resp).unwrap_or(0);
        assert_eq!(n, 0, "dropped connection reads EOF, got {resp:?}");
    }

    // connection 2: the first response is torn mid-line — a malformed
    // frame for the client, but the stream itself keeps serving
    {
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        writeln!(c, "{}", plan_line("t1", 2)).unwrap();
        let mut torn = String::new();
        reader.read_line(&mut torn).unwrap();
        assert!(Json::parse(torn.trim()).is_err(), "first response was torn: {torn:?}");
        writeln!(c, "{}", plan_line("t2", 2)).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(result_of(resp.trim()), reference, "stream recovered after the torn write");
    }

    // a wedged client (connects, never writes) is disconnected by the
    // read deadline instead of pinning a connection thread forever
    {
        let mut dead = TcpStream::connect(addr).unwrap();
        dead.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        std::thread::sleep(Duration::from_millis(600));
        let mut resp = String::new();
        let outcome = writeln!(dead, "{}", plan_line("d1", 2))
            .and_then(|_| BufReader::new(dead.try_clone().unwrap()).read_line(&mut resp));
        assert!(
            matches!(outcome, Ok(0) | Err(_)),
            "wedged peer was cut loose, got {resp:?}"
        );
    }

    // the daemon is still fully alive for a well-behaved client
    {
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        writeln!(c, "{}", plan_line("ok1", 2)).unwrap();
        let mut resp = String::new();
        BufReader::new(c.try_clone().unwrap()).read_line(&mut resp).unwrap();
        assert_eq!(result_of(resp.trim()), reference);
    }

    assert_eq!(failpoint::trip_count("serve.accept_fail"), 1);
    assert_eq!(failpoint::trip_count("serve.write_torn"), 1);
    assert_ledger(&svc);
    svc.drain();
}

/// Exact-lane budget exhaustion at a chosen node: the `--engine exact`
/// request degrades to the DP plan (the documented fallback), never dies
/// and never serves garbage.
#[test]
fn exact_budget_exhaustion_degrades_to_the_dp_plan() {
    let _g = chaos_lock();
    let dp_reference = reference_payload(2, Some("dp"));
    let _d = arm("exact.budget_exhaust:always");

    let svc = PlanService::new(ServeConfig { workers: 1, ..ServeConfig::default() });
    let resp = svc.handle_line(&engine_line("x1", 2, "exact"));
    assert_eq!(
        result_of(&resp),
        dp_reference,
        "exhausted exact lane must serve exactly the DP fallback plan"
    );
    assert!(failpoint::trip_count("exact.budget_exhaust") > 0, "the budget site fired");
    assert_ledger(&svc);
    svc.drain();
}

/// Profile-cache miss storm over a warm persistent cache: every consult
/// is forced cold, costing re-profiling — and the re-profiled plan is
/// byte-identical (the standing "never a wrong plan" invariant).
#[test]
fn profile_cache_miss_storm_costs_reprofiling_never_a_wrong_plan() {
    let _g = chaos_lock();
    let dir = scratch("storm");
    let reference = reference_payload(3, None);
    let cfg = || ServeConfig {
        workers: 1,
        cache_path: Some(dir.join("profiles.json")),
        ..ServeConfig::default()
    };
    {
        let svc = PlanService::new(cfg());
        assert_eq!(result_of(&svc.handle_line(&plan_line("warm", 3))), reference);
        svc.drain();
    }

    let _d = arm("profile_cache.miss_storm:always");
    let svc = PlanService::new(cfg());
    let resp = svc.handle_line(&plan_line("storm", 3));
    assert_eq!(result_of(&resp), reference, "re-profiled plan is byte-identical");
    assert!(svc.stats().profile_misses > 0, "the storm forced cold profiling");
    assert!(failpoint::trip_count("profile_cache.miss_storm") > 0);
    svc.drain();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Stale-lock takeover race: a lock file that *looks* abandoned is
/// claimed, but the injected race makes the post-rename re-check
/// conclude it grabbed a live holder's lock — forcing the hard-link
/// restore path. The save still completes (second claim finds the
/// genuinely stale carcass) and the persisted cache stays valid.
#[test]
fn stale_lock_takeover_race_restores_and_still_saves() {
    let _g = chaos_lock();
    let dir = scratch("stale");
    let reference = reference_payload(2, None);

    // plant a lock whose mtime is long past LOCK_STALE
    let lock_path = dir.join("profiles.json.lock");
    std::fs::write(&lock_path, "424242.0\n").unwrap();
    let old = std::time::SystemTime::now() - Duration::from_secs(60);
    std::fs::File::options()
        .write(true)
        .open(&lock_path)
        .unwrap()
        .set_modified(old)
        .unwrap();

    let _d = arm("profile_cache.stale_race:once");
    let svc = PlanService::new(ServeConfig {
        workers: 1,
        cache_path: Some(dir.join("profiles.json")),
        ..ServeConfig::default()
    });
    let resp = svc.handle_line(&plan_line("s1", 2));
    assert_eq!(result_of(&resp), reference);
    svc.drain();
    assert_eq!(failpoint::trip_count("profile_cache.stale_race"), 1, "the race fired once");

    // the cache survived the contested save: a fresh disarmed service
    // over the same file plans warm with zero re-profiling surprises
    failpoint::disarm_all();
    let svc = PlanService::new(ServeConfig {
        workers: 1,
        cache_path: Some(dir.join("profiles.json")),
        ..ServeConfig::default()
    });
    assert_eq!(result_of(&svc.handle_line(&plan_line("s2", 2))), reference);
    assert!(svc.stats().profile_hits > 0, "the contested save persisted real profiles");
    svc.drain();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The converted `expect("flight published")` site: a coalesced follower
/// whose flight slot is dropped (injected) answers with a structured
/// internal error — the leader's plan is untouched and the ledger still
/// reconciles.
#[test]
fn coalesced_flight_drop_degrades_to_a_structured_error() {
    let _g = chaos_lock();
    let reference = reference_payload(2, None);
    let _d = arm("serve.flight_drop:always");

    let svc = PlanService::new(ServeConfig { workers: 2, ..ServeConfig::default() });
    // hold the leader inside its search until the follower has coalesced
    let probe = svc.clone();
    svc.set_search_hook(Arc::new(move || {
        while probe.stats().coalesced < 1 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }));

    let (leader_resp, follower_resp) = std::thread::scope(|s| {
        let leader = {
            let svc = svc.clone();
            s.spawn(move || svc.handle_line(&plan_line("lead", 2)))
        };
        while svc.stats().plan_misses < 1 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let follower = {
            let svc = svc.clone();
            s.spawn(move || svc.handle_line(&plan_line("join", 2)))
        };
        (leader.join().expect("leader survives"), follower.join().expect("follower survives"))
    });

    assert_eq!(result_of(&leader_resp), reference, "the leader's plan is untouched");
    let j = Json::parse(&follower_resp).unwrap();
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{follower_resp}");
    let msg = j.get("error").and_then(Json::as_str).unwrap_or_default();
    assert!(msg.contains("internal_error"), "structured, not a panic: {follower_resp}");
    assert_eq!(failpoint::trip_count("serve.flight_drop"), 1);

    let st = svc.stats();
    assert_eq!((st.received, st.admitted, st.coalesced), (2, 1, 1));
    assert_ledger(&svc);
    svc.drain();
}

/// The free-when-disarmed guarantee, exercised end to end: with nothing
/// armed, a full serve run's payloads equal the fault-free references,
/// no fault audit appears anywhere, and site evaluations cost nothing
/// observable.
#[test]
fn disarmed_runs_are_byte_identical_and_audit_free() {
    let _g = chaos_lock();
    let reference = reference_payload(2, None);

    let svc = PlanService::new(ServeConfig { workers: 2, ..ServeConfig::default() });
    assert_eq!(result_of(&svc.handle_line(&plan_line("d1", 2))), reference);
    assert_eq!(result_of(&svc.handle_line(&plan_line("d2", 2))), reference);

    // disarmed stats responses carry no fault audit (byte-compat with
    // pre-framework behavior)
    let stats_resp = svc.handle_line("{\"id\": \"st\", \"type\": \"stats\"}");
    let sj = Json::parse(&stats_resp).unwrap();
    assert!(
        sj.get("result").and_then(|r| r.get("faults")).is_none(),
        "disarmed stats must not grow a faults key: {stats_resp}"
    );
    assert!(cfp::obs::fault_counters().is_empty());
    assert!(!failpoint::should_trip("profile_cache.torn_save"));
    assert_ledger(&svc);
    svc.drain();
}
