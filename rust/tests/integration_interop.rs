//! Integration tests for the two-level (inter-op × intra-op) planner:
//! the stage-split DP against brute-force split enumeration, the k = 1
//! degenerate case against today's single-stage plans (bit-identical),
//! the composed step time against the event-driven schedule simulation,
//! and the acceptance bar on the harness eval presets (never slower than
//! single-stage; strictly beats the naive equal-split pipeline
//! somewhere).

use cfp::cluster::{simulate_pipeline, Platform};
use cfp::coordinator::{run_cfp, run_cfp_two_level, CfpOptions};
use cfp::harness::{pipeline_eval_models, pipeline_row};
use cfp::interop::{
    brute_force_splits, build_context, plan_fixed_stages, PipelineOptions, StageSpec,
};
use cfp::models::{build_training, ModelCfg};
use cfp::profiler::{CacheHandle, ProfileCache};
use cfp::spmd::Mesh;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cfp-interop-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn degenerate_single_stage_reproduces_cfp_plan_bit_identically() {
    let opts = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(3),
        Platform::a100_pcie(4),
    )
    .with_stages(StageSpec::Single);
    let two = run_cfp_two_level(&opts);
    let single = run_cfp(&opts);
    let pipeline = two.pipeline.expect("legacy single-stage spec is always feasible");

    assert_eq!(pipeline.num_stages(), 1);
    let st = &pipeline.stages[0];
    assert_eq!(st.plan.choice, single.plan.choice, "same intra-op plan");
    assert!(st.plan.time_us == single.plan.time_us, "time must be bit-identical");
    assert_eq!(st.plan.mem_bytes, single.plan.mem_bytes);
    // k = 1 bypasses the microbatch division: the composed step time IS
    // the single-stage plan time, not m · (T/m)
    assert!(pipeline.step_time_us == single.plan.time_us);
    assert_eq!(pipeline.bubble_fraction, 0.0);
    assert_eq!(st.p2p_in_us, 0.0);
    assert!(st.remat.iter().all(|&r| !r), "legacy mode never recomputes");
    // whole-batch 1F1B accounting of a single stage == the plan memory
    assert_eq!(pipeline.peak_mem_bytes, single.plan.mem_bytes);
}

#[test]
fn stage_split_dp_matches_brute_force_enumeration() {
    // 4 layers keep the chain small (the ISSUE's "chains ≤ 6" regime);
    // the sub-mesh size is irrelevant to DP-vs-brute-force equality.
    let g = build_training(&ModelCfg::preset("gpt-tiny").with_layers(4));
    let popts = PipelineOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    let ctx = build_context(&g, &popts, 2, CacheHandle::None);
    let n = ctx.segments.instances.len();
    assert!(n >= 2, "need a chain to split, got {n} instances");
    for k in 1..=n.min(4) {
        let dp = plan_fixed_stages(&g, &ctx, &popts, k).map(|p| p.step_time_us);
        let bf = brute_force_splits(&g, &ctx, &popts, k);
        match (dp, bf) {
            (Some(d), Some(b)) => {
                assert!(
                    (d - b).abs() <= 1e-6 * b.max(1.0),
                    "k={k}: dp {d} vs brute force {b}"
                );
            }
            (None, None) => {}
            (d, b) => panic!("k={k}: feasibility mismatch {d:?} vs {b:?}"),
        }
    }
}

#[test]
fn dp_is_exact_across_microbatch_counts() {
    // the (sum, max) Pareto state must stay exact for every bubble weight
    let g = build_training(&ModelCfg::preset("moe-tiny").with_layers(4));
    let popts = PipelineOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    let ctx = build_context(&g, &popts, 2, CacheHandle::None);
    let n = ctx.segments.instances.len();
    for m in [1usize, 2, 8, 32] {
        let mut p = popts.clone();
        p.microbatches = m;
        for k in 2..=n.min(3) {
            let dp = plan_fixed_stages(&g, &ctx, &p, k).map(|x| x.step_time_us);
            let bf = brute_force_splits(&g, &ctx, &p, k);
            match (dp, bf) {
                (Some(d), Some(b)) => {
                    assert!((d - b).abs() <= 1e-6 * b.max(1.0), "m={m} k={k}: {d} vs {b}");
                }
                (None, None) => {}
                (d, b) => panic!("m={m} k={k}: feasibility mismatch {d:?} vs {b:?}"),
            }
        }
    }
}

#[test]
fn composed_step_time_matches_schedule_simulation() {
    let g = build_training(&ModelCfg::preset("gpt-tiny").with_layers(4));
    let popts = PipelineOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    let ctx = build_context(&g, &popts, 2, CacheHandle::None);
    let p = plan_fixed_stages(&g, &ctx, &popts, 2).expect("2-stage plan for a 4-layer chain");
    assert_eq!(p.num_stages(), 2);
    let lats: Vec<f64> = p.stages.iter().map(|s| s.latency_us).collect();
    let sim = simulate_pipeline(&lats, p.microbatches);
    assert!(
        (sim.makespan_us - p.step_time_us).abs() <= 1e-6 * p.step_time_us.max(1.0),
        "schedule sim {} vs composed {}",
        sim.makespan_us,
        p.step_time_us
    );
    // stages partition the chain contiguously
    assert_eq!(p.stages[0].span.0, 0);
    assert_eq!(p.stages[0].span.1, p.stages[1].span.0);
    assert_eq!(p.stages[1].span.1, ctx.segments.instances.len());
    assert!(p.stages[1].p2p_in_us > 0.0, "a cut moves one activation tensor");
}

#[test]
fn two_level_never_slower_than_single_and_beats_naive_somewhere() {
    // the acceptance bar: on the harness eval presets the two-level step
    // time is ≤ the single-stage CFP plan everywhere (k = 1 is in the
    // search space) and strictly below the naive equal-split pipeline on
    // at least one preset.
    let mut strict_win = false;
    let mut summary: Vec<(String, f64, f64, f64)> = Vec::new();
    for model in pipeline_eval_models() {
        let (row, _) =
            pipeline_row(&model, Platform::a100_pcie(4).scaled_testbed(), Mesh::flat(4), 8);
        assert!(
            row.two_level_us <= row.single_us + 1e-9,
            "{}: two-level {} vs single {}",
            row.model,
            row.two_level_us,
            row.single_us
        );
        if row.two_level_us < row.naive_us {
            strict_win = true;
        }
        summary.push((row.model, row.single_us, row.two_level_us, row.naive_us));
    }
    // the two-node testbed: pipelining across the slow inter-node link is
    // where staging pays most clearly
    let models = pipeline_eval_models();
    let (row, r) = pipeline_row(
        &models[0],
        Platform::a100_two_node().scaled_testbed(),
        Mesh { intra: 8, nodes: 2 },
        8,
    );
    assert!(row.two_level_us <= row.single_us + 1e-9, "2-node gpt");
    assert!(r.pipeline.as_ref().unwrap().num_stages() >= 1);
    if row.two_level_us < row.naive_us {
        strict_win = true;
    }
    summary.push((format!("{}@2node", row.model), row.single_us, row.two_level_us, row.naive_us));
    assert!(
        strict_win,
        "two-level must strictly beat the naive pipeline somewhere: {summary:?}"
    );
}

#[test]
fn parallel_plan_pipeline_bit_identical_at_any_thread_count() {
    // the sweep jobs fan out over the pool with order-preserving
    // collection, so the composed plan must match the serial path
    // bit-for-bit at every thread count, in both planner modes
    use cfp::interop::{plan_pipeline, StageContexts};
    use cfp::memory::RecomputeSpec;

    let g = build_training(&ModelCfg::preset("gpt-tiny").with_layers(4));
    let mut popts = PipelineOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    popts.spec = StageSpec::Auto;
    let mut ctxs = StageContexts::new();
    ctxs.ensure_all(&g, &popts, CacheHandle::None);

    for memory_aware in [false, true] {
        let mut serial = popts.clone();
        if memory_aware {
            serial.recompute = RecomputeSpec::Auto;
        }
        serial.threads = 1;
        let want = plan_pipeline(&g, &ctxs, &serial).expect("uncapped planning is feasible");
        for threads in [2usize, 4, 7] {
            let mut par = serial.clone();
            par.threads = threads;
            let got = plan_pipeline(&g, &ctxs, &par).expect("same feasibility");
            assert!(
                got.step_time_us == want.step_time_us,
                "threads={threads} memory_aware={memory_aware}: {} vs {}",
                got.step_time_us,
                want.step_time_us
            );
            assert_eq!(got.num_stages(), want.num_stages(), "threads={threads}");
            for (a, b) in got.stages.iter().zip(&want.stages) {
                assert_eq!(a.span, b.span, "threads={threads}");
                assert_eq!(a.plan.choice, b.plan.choice, "threads={threads}");
                assert!(a.plan.time_us == b.plan.time_us, "threads={threads}");
                assert_eq!(a.plan.mem_bytes, b.plan.mem_bytes, "threads={threads}");
                assert_eq!(a.remat, b.remat, "threads={threads}");
            }
        }
    }
}

#[test]
fn warm_cache_serves_every_stage_count_and_plans_round_trip() {
    let dir = temp_dir("warm");
    let path = dir.join("profiles.json");
    let opts = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(2),
        Platform::a100_pcie(4),
    )
    .with_cache(&path)
    .with_stages(StageSpec::Auto);

    let cold = run_cfp_two_level(&opts);
    let warm = run_cfp_two_level(&opts);
    // the single-stage context is fully warm...
    assert_eq!(warm.single.db.stats.cache_misses, 0);
    // ...and the composed plans are bit-identical (profiles round-trip
    // exactly through the JSON cache for every sub-mesh context)
    let (cold_p, warm_p) = (cold.pipeline.unwrap(), warm.pipeline.unwrap());
    assert_eq!(warm_p.num_stages(), cold_p.num_stages());
    assert!(warm_p.step_time_us == cold_p.step_time_us);
    assert_eq!(warm_p.mem_bytes, cold_p.mem_bytes);
    assert_eq!(warm_p.peak_mem_bytes, cold_p.peak_mem_bytes, "memory columns round-trip");
    for (a, b) in warm_p.stages.iter().zip(&cold_p.stages) {
        assert_eq!(a.span, b.span);
        assert_eq!(a.plan.choice, b.plan.choice);
        assert_eq!(a.peak_mem_bytes, b.peak_mem_bytes);
    }
    assert!(warm.naive.unwrap().step_time_us == cold.naive.unwrap().step_time_us);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bounded_cache_evicts_but_never_changes_plans() {
    let dir = temp_dir("bounded");
    let path = dir.join("profiles.json");
    let mut opts = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(2),
        Platform::a100_pcie(4),
    )
    .with_cache(&path);
    opts.cache_max_entries = Some(2);

    let a = run_cfp(&opts);
    let b = run_cfp(&opts); // partially warm: some entries were evicted
    assert_eq!(a.plan.choice, b.plan.choice, "eviction costs re-profiling, never the plan");
    assert!(a.plan.time_us == b.plan.time_us);

    let reloaded = ProfileCache::open(&path);
    assert!(
        reloaded.num_segments() + reloaded.num_reshards() <= 2,
        "file respects the bound: {} + {}",
        reloaded.num_segments(),
        reloaded.num_reshards()
    );
    std::fs::remove_dir_all(&dir).ok();
}
