//! Differential property suite for the PR 8 SP-DAG planner: the
//! recursive series-parallel DP lanes (`spdag::sp_search_span`,
//! `sp_search_mem_span`) vs the SP-DAG branch-and-bound oracle
//! (`spdag::sp_search_span_exact`, `sp_search_mem_span_exact`) on
//! randomized small fork/join topologies, plus the structural
//! `decompose`/`recompose` round-trip and the event-simulation replay.
//!
//! Instances stay small (trunk 1–2, 1–2 groups of 2–3 branches ×
//! 1–2 instances, ≤ 3 configs) so exhaustive enumeration is cheap.
//! Lanes mirror `prop_exact_equivalence`:
//!
//! * **unconstrained scalar** — DP optimum == exact optimum
//!   bit-for-bit on every valid span, and the fixed-choice replay
//!   (`sp_plan_cost_span`) and the event simulation
//!   (`simulate_sp_dag(sim_tasks(..))`) both reproduce the DP's time
//!   bit-for-bit.
//! * **capped** — the two-valued memory family keeps every per-state
//!   Pareto frontier under `FRONTIER_CAP` (a span of length L has
//!   ≤ L + 1 distinct memory sums), so thinning never engages and the
//!   capped DP must be bit-identical to exact at every cap.
//! * **memory frontier** — the min-time head matches the untruncated
//!   true-dominance oracle bit-for-bit, every DP point is
//!   dominated-or-equal by an exact point, and feasibility selection
//!   over the exact frontier never loses to the DP's.
//!
//! Failures replay with `CFP_PROP_SEED=<printed value>`.

use cfp::cluster::sim::simulate_sp_dag;
use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::cost::{self, SearchCtx};
use cfp::memory::{self, RecomputeSpec};
use cfp::models::ModelCfg;
use cfp::profiler::{ProfileDb, ReshardTable, SegmentConfig, SegmentProfile};
use cfp::segment::{SegmentInstance, SegmentSet, UniqueSegment};
use cfp::spdag::{
    self, decompose, recompose, sp_plan_cost_span, sp_search_mem_span, sp_search_mem_span_exact,
    sp_search_span, sp_search_span_exact, BranchGroup, SpCtx, SpTopology,
};
use cfp::spmd::ShardState;
use cfp::util::proptest::Prop as Harness;
use cfp::util::Pcg64;

/// Per-config memory draw: free random bytes, or the `base + {0, delta}`
/// two-value family the capped lane's no-thinning argument needs.
enum MemModel {
    Free,
    TwoValued { delta: u64 },
}

fn random_profile(rng: &mut Pcg64, cfgs: usize, mem: &MemModel) -> SegmentProfile {
    let base = 500 + rng.below(4000);
    let mem_bytes: Vec<u64> = (0..cfgs)
        .map(|_| match mem {
            MemModel::Free => 500 + rng.below(4000),
            MemModel::TwoValued { delta } => base + rng.below(2) * delta,
        })
        .collect();
    let act_bytes: Vec<u64> = mem_bytes.iter().map(|&m| rng.below(m + 1)).collect();
    let ckpt_bytes: Vec<u64> = act_bytes.iter().map(|&a| rng.below(a + 1)).collect();
    SegmentProfile {
        configs: (0..cfgs).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
        t_c_us: (0..cfgs).map(|_| rng.f64() * 200.0).collect(),
        t_p_us: (0..cfgs).map(|_| rng.f64() * 400.0).collect(),
        mem_bytes,
        act_bytes,
        ckpt_bytes,
        t_fwd_us: (0..cfgs).map(|_| rng.f64() * 100.0).collect(),
        symbolic_volume: vec![0; cfgs],
        boundary_out: vec![ShardState::Replicated; cfgs],
        boundary_in: vec![ShardState::Replicated; cfgs],
    }
}

/// A small random SP-DAG setup: 1–2 trunk instances, then 1–2 fork/join
/// groups of 2–3 branches × 1–2 instances each (one merge-successor
/// trunk instance after every group), over ≤ 3 uniques × ≤ 3 configs.
/// Reshard tables are absent for ~1/3 of the pairs (dense 0.0 default).
fn random_spdag(rng: &mut Pcg64, mem: MemModel) -> (SegmentSet, ProfileDb, SpTopology) {
    let uniques = 1 + rng.below(3) as usize;
    let mut db = ProfileDb::default();
    for _ in 0..uniques {
        let cfgs = 1 + rng.below(3) as usize;
        db.segments.push(random_profile(rng, cfgs, &mem));
    }
    for a in 0..uniques {
        for b in 0..uniques {
            if rng.below(3) > 0 {
                let (ca, cb) = (db.segments[a].configs.len(), db.segments[b].configs.len());
                let t_r_us: Vec<Vec<f64>> =
                    (0..ca).map(|_| (0..cb).map(|_| rng.f64() * 50.0).collect()).collect();
                db.reshard.insert(
                    (a, b),
                    ReshardTable { t_r_us, sym_vol: vec![vec![0; cb]; ca], programs: ca * cb },
                );
            }
        }
    }
    let trunk = 1 + rng.below(2) as usize;
    let groups = 1 + rng.below(2) as usize;
    let mut topo_groups = Vec::with_capacity(groups);
    let mut pos = trunk;
    for _ in 0..groups {
        let branches = 2 + rng.below(2) as usize;
        let branch_len = 1 + rng.below(2) as usize;
        let ranges: Vec<(usize, usize)> = (0..branches)
            .map(|b| (pos + b * branch_len, pos + (b + 1) * branch_len))
            .collect();
        topo_groups.push(BranchGroup { branches: ranges });
        pos += branches * branch_len + 1; // branches + merge successor
    }
    let n = pos;
    let topo = SpTopology { n, groups: topo_groups };
    topo.validate().expect("generated topology is valid by construction");

    let uids: Vec<usize> = (0..n).map(|_| rng.below(uniques as u64) as usize).collect();
    let instances: Vec<SegmentInstance> = uids
        .iter()
        .map(|&u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
        .collect();
    let unique: Vec<UniqueSegment> = (0..uniques)
        .map(|u| UniqueSegment {
            id: u,
            fingerprint: format!("u{u}"),
            rep: uids.iter().position(|&x| x == u).unwrap_or(0),
            count: uids.iter().filter(|&&x| x == u).count(),
        })
        .collect();
    (SegmentSet { instances, unique }, db, topo)
}

/// A random span whose endpoints are both valid cuts (never inside a
/// branch group) — the only spans the SP-DAG searchers accept.
fn random_valid_span(rng: &mut Pcg64, topo: &SpTopology) -> (usize, usize) {
    let cuts: Vec<usize> = (0..=topo.n).filter(|&p| topo.valid_cut(p)).collect();
    let i = rng.below((cuts.len() - 1) as u64) as usize;
    let j = i + 1 + rng.below((cuts.len() - 1 - i) as u64) as usize;
    (cuts[i], cuts[j])
}

fn assert_times_eq(a: &Option<cost::Plan>, b: &Option<cost::Plan>, what: &str) {
    match (a, b) {
        (Some(a), Some(b)) => {
            assert!(
                a.time_us.to_bits() == b.time_us.to_bits(),
                "{what}: time {} vs {}",
                a.time_us,
                b.time_us
            );
        }
        (None, None) => {}
        _ => panic!("{what}: feasibility mismatch {a:?} vs {b:?}"),
    }
}

#[test]
fn prop_unconstrained_spdag_dp_equals_exact_and_replays() {
    Harness::fuzz(500, 0x59DA61).check("SP-DAG unconstrained DP ≡ exact ≡ sim", |rng| {
        let (ss, db, topo) = random_spdag(rng, MemModel::Free);
        let ctx = SearchCtx::new(&ss, &db);
        let sp = SpCtx::new(&ctx, &topo, &db);
        let n = topo.n;
        let mut spans = vec![(0, n)];
        spans.push(random_valid_span(rng, &topo));
        for (lo, hi) in spans {
            let dp = sp_search_span(&ctx, &sp, None, lo, hi);
            let ex = sp_search_span_exact(&ctx, &sp, None, lo, hi);
            assert_times_eq(&dp, &ex, &format!("[{lo},{hi})"));
            let plan = dp.expect("uncapped SP-DAG search is always feasible");
            // the fixed-choice replay shares the DP's float association
            let (t, m) = sp_plan_cost_span(&ctx, &sp, &plan.choice, lo, hi);
            assert!(
                t.to_bits() == plan.time_us.to_bits(),
                "[{lo},{hi}): replay {t} vs plan {}",
                plan.time_us
            );
            assert_eq!(m, plan.mem_bytes, "[{lo},{hi}): replay memory");
            // and the event-driven simulation reproduces the closed form
            let tasks = spdag::sim_tasks(&ctx, &sp, &plan.choice, lo, hi);
            let fin = simulate_sp_dag(&tasks);
            let makespan = fin.last().copied().expect("non-empty span");
            assert!(
                makespan.to_bits() == plan.time_us.to_bits(),
                "[{lo},{hi}): sim {makespan} vs plan {}",
                plan.time_us
            );
        }
    });
}

#[test]
fn prop_capped_spdag_dp_equals_exact() {
    Harness::fuzz(500, 0xCA99DA).check("SP-DAG capped DP ≡ exact", |rng| {
        let delta = 1 + rng.below(2000);
        let (ss, db, topo) = random_spdag(rng, MemModel::TwoValued { delta });
        let ctx = SearchCtx::new(&ss, &db);
        let sp = SpCtx::new(&ctx, &topo, &db);
        let n = topo.n;
        let free = sp_search_span(&ctx, &sp, None, 0, n).expect("uncapped is feasible");
        let caps = [
            1u64,
            free.mem_bytes.saturating_sub(delta),
            free.mem_bytes.saturating_sub(1),
            free.mem_bytes,
            free.mem_bytes + rng.below(4 * delta + 1),
        ];
        for (lo, hi) in [(0, n), random_valid_span(rng, &topo)] {
            for cap in caps {
                let dp = sp_search_span(&ctx, &sp, Some(cap), lo, hi);
                let ex = sp_search_span_exact(&ctx, &sp, Some(cap), lo, hi);
                assert_times_eq(&dp, &ex, &format!("[{lo},{hi}) cap {cap}"));
                if let Some(e) = &ex {
                    assert!(e.mem_bytes <= cap, "[{lo},{hi}) cap {cap}: exact plan fits");
                }
            }
        }
    });
}

#[test]
fn prop_spdag_mem_frontier_head_matches_and_exact_dominates() {
    Harness::fuzz(500, 0x3FDA6).check("SP-DAG mem frontier: head ≡, exact dominates", |rng| {
        let (ss, db, topo) = random_spdag(rng, MemModel::Free);
        let ctx = SearchCtx::new(&ss, &db);
        let sp = SpCtx::new(&ctx, &topo, &db);
        let n = topo.n;
        let spec = if rng.below(2) == 0 { RecomputeSpec::Off } else { RecomputeSpec::Auto };
        for (lo, hi) in [(0, n), random_valid_span(rng, &topo)] {
            let dp = sp_search_mem_span(&ctx, &sp, lo, hi, spec);
            let ex = sp_search_mem_span_exact(&ctx, &sp, lo, hi, spec);
            assert!(!dp.is_empty() && !ex.is_empty(), "[{lo},{hi}) {spec:?}");

            // the min-time head survives every prune, so its time must
            // agree bit-for-bit. (Unlike the chain suite, head *choice*
            // equality is not asserted: two branches with identical
            // unique sequences admit time-tied optima under a config
            // swap, and the tied representative may legitimately differ.)
            let (dh, eh) = (&dp[0], &ex[0]);
            assert!(
                dh.time_us.to_bits() == eh.time_us.to_bits(),
                "[{lo},{hi}) {spec:?}: head {} vs {}",
                dh.time_us,
                eh.time_us
            );

            // completeness: every DP point is covered by an exact point
            for p in &dp {
                assert!(
                    ex.iter().any(|q| q.time_us <= p.time_us
                        && q.footprint.static_bytes <= p.footprint.static_bytes
                        && q.footprint.retained_bytes <= p.footprint.retained_bytes
                        && q.footprint.transient_bytes <= p.footprint.transient_bytes),
                    "[{lo},{hi}) {spec:?}: DP point t={} not covered",
                    p.time_us
                );
            }

            // feasibility selection over exact never loses to the DP's
            let me = 1 + rng.below(8) as usize;
            let f = 1 + rng.below(4) as usize;
            let caps: Vec<u64> =
                dp.iter().map(|p| p.peak_bytes(me, f)).chain([0, u64::MAX]).collect();
            for cap in caps {
                let from_dp = memory::select_feasible(&dp, me, f, cap).map(|p| p.time_us);
                let from_ex = memory::select_feasible(&ex, me, f, cap).map(|p| p.time_us);
                match (from_dp, from_ex) {
                    (Some(d), Some(e)) => {
                        assert!(e <= d, "cap {cap}: exact selection {e} worse than DP {d}")
                    }
                    (None, Some(_)) => {} // the DP's documented thinning loss
                    (Some(d), None) => {
                        panic!("cap {cap}: DP feasible at {d} but exact claims infeasible")
                    }
                    (None, None) => {}
                }
            }
            let d = memory::select_feasible(&dp, me, f, u64::MAX).unwrap();
            let e = memory::select_feasible(&ex, me, f, u64::MAX).unwrap();
            assert!(d.time_us.to_bits() == e.time_us.to_bits());
        }
    });
}

#[test]
fn prop_sp_decomposition_round_trips() {
    Harness::fuzz(500, 0x4EE7).check("decompose ∘ recompose identity", |rng| {
        let (_, _, topo) = random_spdag(rng, MemModel::Free);
        let tree = decompose(&topo);
        let back = recompose(&tree).expect("decompose output is always recomposable");
        assert_eq!(back, topo, "recompose(decompose(t)) == t");
        assert_eq!(decompose(&back), tree, "decompose(recompose(tree)) == tree");
    });
}

#[test]
fn chain_topologies_decompose_to_one_leaf() {
    let topo = SpTopology::chain(7);
    let tree = decompose(&topo);
    assert_eq!(
        tree,
        spdag::SpTree::Series(vec![spdag::SpTree::Leaf { lo: 0, hi: 7 }]),
        "a chain is a single trunk leaf"
    );
    assert_eq!(recompose(&tree).unwrap(), topo);
}

/// End-to-end pin on every expert-parallel MoE preset: the planner's
/// chosen time, the fixed-choice replay, and the event-driven DAG
/// simulation must all agree bit-for-bit (the standing `cluster::sim`
/// invariant, extended to the SP-DAG lane).
#[test]
fn moe_presets_plan_replay_and_simulate_bit_identically() {
    let models = [
        ModelCfg::preset("moe-ep-tiny").with_layers(2),
        ModelCfg::preset("moe-ep-tiny").with_layers(4),
        ModelCfg::preset("moe-ep-7.1b").with_layers(2).with_batch(8).scaled_for_eval(),
    ];
    for model in models {
        let name = model.name.clone();
        let layers = model.layers;
        let opts = CfpOptions::new(model, Platform::a100_pcie(4));
        let r = run_cfp(&opts);
        assert!(!r.topo.is_chain(), "{name} l{layers}: expert branches make an SP-DAG");
        let ctx = SearchCtx::new(&r.segments, &r.db);
        let sp = SpCtx::new(&ctx, &r.topo, &r.db);
        let n = r.segments.instances.len();
        let (t, m) = sp_plan_cost_span(&ctx, &sp, &r.plan.choice, 0, n);
        assert!(
            t.to_bits() == r.plan.time_us.to_bits(),
            "{name} l{layers}: replay {t} vs plan {}",
            r.plan.time_us
        );
        assert_eq!(m, r.plan.mem_bytes, "{name} l{layers}: replay memory");
        let tasks = spdag::sim_tasks(&ctx, &sp, &r.plan.choice, 0, n);
        let fin = simulate_sp_dag(&tasks);
        let makespan = fin.last().copied().expect("non-empty task list");
        assert!(
            makespan.to_bits() == r.plan.time_us.to_bits(),
            "{name} l{layers}: sim {makespan} vs plan {}",
            r.plan.time_us
        );
    }
}
