//! Integration tests for the persistent profile cache: a cold `run_cfp`
//! populates the on-disk cache; a warm rerun (fresh process state — the
//! cache is re-opened from disk) must produce a bit-identical plan while
//! skipping the MetricsProfiling phase entirely.

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::models::ModelCfg;
use cfp::profiler::{CacheKey, ProfileCache, SegmentConfig, SegmentProfile};
use cfp::spmd::ShardState;

fn temp_cache_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cfp-itest-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn warm_cache_plan_is_bit_identical_and_profiling_is_skipped() {
    let dir = temp_cache_dir("warm");
    let path = dir.join("profiles.json");
    let opts = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(3),
        Platform::a100_pcie(4),
    )
    .with_cache(&path);

    let cold = run_cfp(&opts);
    assert_eq!(cold.db.stats.cache_hits, 0, "first run starts from an empty cache");
    assert!(cold.db.stats.cache_misses > 0);
    assert!(cold.db.stats.profile_wall_s > 0.0);
    assert!(path.exists(), "cache file written on save");

    // second run: the cache is re-opened from disk, as a new process would
    let warm = run_cfp(&opts);
    assert_eq!(warm.db.stats.cache_misses, 0, "everything served from cache");
    assert_eq!(warm.db.stats.cache_hits, cold.db.stats.cache_misses);

    // MetricsProfiling is a lookup now: exactly zero profiled wall
    assert_eq!(warm.db.stats.profile_wall_s, 0.0);
    assert_eq!(warm.timings.metrics_profiling_s, 0.0);

    // bit-identical plan and composed database
    assert_eq!(warm.plan.choice, cold.plan.choice);
    assert!(warm.plan.time_us == cold.plan.time_us, "time must round-trip exactly");
    assert_eq!(warm.plan.mem_bytes, cold.plan.mem_bytes);
    assert_eq!(warm.db.segments, cold.db.segments);
    assert_eq!(warm.db.reshard, cold.db.reshard);
    assert_eq!(warm.db.profile_space(), cold.db.profile_space());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cache_invalidates_across_platforms_and_models() {
    let dir = temp_cache_dir("invalidate");
    let path = dir.join("profiles.json");

    let a100 = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(2),
        Platform::a100_pcie(4),
    )
    .with_cache(&path);
    let first = run_cfp(&a100);
    assert!(first.db.stats.cache_misses > 0);

    // different platform: same fingerprints, different signature → misses
    let v100 = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(2),
        Platform::v100_nvlink(),
    )
    .with_cache(&path);
    let other = run_cfp(&v100);
    assert_eq!(other.db.stats.cache_hits, 0, "v100 must not reuse a100 profiles");

    // different model shape: different fingerprints → misses
    let wider = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(2).with_batch(16),
        Platform::a100_pcie(4),
    )
    .with_cache(&path);
    let wide = run_cfp(&wider);
    assert_eq!(wide.db.stats.cache_hits, 0, "batch change must invalidate");

    // and the original still hits everything
    let again = run_cfp(&a100);
    assert_eq!(again.db.stats.cache_misses, 0);
    assert_eq!(again.plan.choice, first.plan.choice);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_cache_file_degrades_to_cold_run() {
    let dir = temp_cache_dir("corrupt");
    let path = dir.join("profiles.json");
    std::fs::write(&path, "{ this is not json").unwrap();

    let opts = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(2),
        Platform::a100_pcie(4),
    )
    .with_cache(&path);
    let r = run_cfp(&opts);
    assert!(r.db.stats.cache_misses > 0);
    assert_eq!(r.db.stats.cache_hits, 0);

    // the bad file was replaced by a valid one
    let reopened = ProfileCache::open(&path);
    assert_eq!(reopened.num_segments(), r.segments.num_unique());

    std::fs::remove_dir_all(&dir).ok();
}

fn probe_profile(tag: u64) -> SegmentProfile {
    SegmentProfile {
        configs: vec![SegmentConfig { strategy: vec![0] }],
        t_c_us: vec![tag as f64],
        t_p_us: vec![1.0],
        mem_bytes: vec![tag],
        act_bytes: vec![tag / 2],
        ckpt_bytes: vec![tag / 8],
        t_fwd_us: vec![0.5],
        symbolic_volume: vec![0],
        boundary_out: vec![ShardState::Replicated],
        boundary_in: vec![ShardState::Replicated],
    }
}

fn key(fp: &str) -> CacheKey {
    CacheKey { fingerprint: fp.to_string(), platform: "sig".into(), parts: 2 }
}

#[test]
fn concurrent_writer_merge_respects_lru_eviction_and_own_entries_win() {
    // Two cache handles share one file, as two processes would. Writer A
    // saves three entries; writer B (opened before A saved, so A's
    // entries are "foreign" to it) has a bound of 3, its own fresher
    // entries, and one key conflicting with A. B's save must fold A's
    // entries in, keep B's version on the conflict, and evict in LRU
    // order across own + merged entries.
    let dir = temp_cache_dir("merge-lru");
    let path = dir.join("profiles.json");

    let mut a = ProfileCache::open(&path);
    let mut b = ProfileCache::open(&path);

    a.put_segment(key("fpA1"), probe_profile(100)); // stamp 1 in A's clock
    a.put_segment(key("fpA2"), probe_profile(200)); // stamp 2
    a.put_segment(key("shared"), probe_profile(300)); // stamp 3
    a.save().unwrap();

    b.set_max_entries(Some(3));
    b.put_segment(key("shared"), probe_profile(999)); // B's own version
    b.put_segment(key("fpB1"), probe_profile(400));
    // touch B's entries so their stamps are fresher than A's
    assert!(b.get_segment(&key("shared")).is_some());
    assert!(b.get_segment(&key("fpB1")).is_some());
    b.save().unwrap();

    let mut merged = ProfileCache::open(&path);
    assert_eq!(merged.num_segments() + merged.num_reshards(), 3, "bound holds on disk");
    // own entries win the key conflict
    let shared = merged.get_segment(&key("shared")).expect("shared survives");
    assert_eq!(shared.mem_bytes, vec![999], "B's version, not A's");
    // B's own fresh entry survives; the least-recently-used foreign entry
    // (A's first) was evicted, the fresher foreign one kept
    assert!(merged.get_segment(&key("fpB1")).is_some(), "own fresh entry survives");
    assert!(merged.get_segment(&key("fpA1")).is_none(), "oldest foreign entry evicted");
    assert!(merged.get_segment(&key("fpA2")).is_some(), "fresher foreign entry kept");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn threaded_cold_run_matches_serial_cold_run() {
    // the warm/cold guarantee composes with profiling parallelism: a
    // threaded cold run must fill the cache with the same numbers
    let serial = run_cfp(&CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(2),
        Platform::a100_pcie(4),
    ));
    let mut topts = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(2),
        Platform::a100_pcie(4),
    );
    topts.threads = 4;
    let threaded = run_cfp(&topts);
    assert_eq!(serial.plan.choice, threaded.plan.choice);
    assert!(serial.plan.time_us == threaded.plan.time_us);
    assert_eq!(serial.db.segments, threaded.db.segments);
}
