//! Integration tests: the full CFP pipeline across models, platforms and
//! meshes, checking the paper's qualitative results end to end.

use cfp::baselines;
use cfp::cluster::Platform;
use cfp::coordinator::{compare_frameworks, run_cfp, CfpOptions};
use cfp::cost;
use cfp::models::ModelCfg;
use cfp::spmd::Mesh;

fn opts(preset: &str, layers: usize, platform: Platform, mesh: Mesh) -> CfpOptions {
    let model = ModelCfg::preset(preset).with_layers(layers).with_batch(8).scaled_for_eval();
    let mut o = CfpOptions::new(model, platform);
    o.mesh = mesh;
    o
}

#[test]
fn all_models_all_platforms_produce_plans() {
    for preset in ["bert-large", "gpt-2.6b", "llama-7b", "moe-7.1b"] {
        for (platform, mesh) in [
            (Platform::a100_pcie(4), Mesh::flat(4)),
            (Platform::v100_nvlink(), Mesh::flat(4)),
        ] {
            let r = run_cfp(&opts(preset, 4, platform, mesh));
            assert!(r.plan.time_us > 0.0, "{preset}/{}", platform.name);
            assert!(r.plan.mem_bytes > 0, "{preset}/{}", platform.name);
            assert_eq!(r.plan.choice.len(), r.segments.instances.len());
        }
    }
}

#[test]
fn cfp_beats_or_matches_every_baseline_everywhere() {
    // §5.2's core claim, across the whole evaluation matrix
    for preset in ["gpt-2.6b", "llama-7b", "moe-7.1b"] {
        for (platform, mesh) in [
            (Platform::a100_pcie(4), Mesh::flat(4)),
            (Platform::a100_pcie(8), Mesh::flat(8)),
            (Platform::v100_nvlink(), Mesh::flat(4)),
        ] {
            let c = compare_frameworks(&opts(preset, 4, platform, mesh));
            for (name, p) in [("ddp", &c.ddp), ("megatron", &c.megatron), ("alpa", &c.alpa)] {
                assert!(
                    c.cfp.time_us <= p.time_us * 1.0001,
                    "{preset}/{}: cfp {} vs {name} {}",
                    platform.name,
                    c.cfp.time_us,
                    p.time_us
                );
            }
        }
    }
}

#[test]
fn moe_gap_largest_on_pcie() {
    // §5.2: MoE@PCIe is where Alpa loses big (expert-parallel AllToAll →
    // SendRecv); on NVLink the gap shrinks
    let pcie = compare_frameworks(&opts("moe-7.1b", 4, Platform::a100_pcie(4), Mesh::flat(4)));
    let nv = compare_frameworks(&opts("moe-7.1b", 4, Platform::v100_nvlink(), Mesh::flat(4)));
    let gap_pcie = pcie.alpa.time_us / pcie.cfp.time_us;
    let gap_nv = nv.alpa.time_us / nv.cfp.time_us;
    assert!(
        gap_pcie >= gap_nv * 0.95,
        "pcie gap {gap_pcie:.2} should be ≥ nvlink gap {gap_nv:.2}"
    );
}

#[test]
fn profile_space_depth_independent() {
    // §5.6: deeper model, same profiling space
    let r4 = run_cfp(&opts("gpt-2.6b", 4, Platform::a100_pcie(4), Mesh::flat(4)));
    let r16 = run_cfp(&opts("gpt-2.6b", 16, Platform::a100_pcie(4), Mesh::flat(4)));
    assert_eq!(
        r4.db.profile_space(),
        r16.db.profile_space(),
        "profile space grew with depth"
    );
}

#[test]
fn memory_cap_changes_plan_not_feasibility() {
    let base = run_cfp(&opts("llama-7b", 6, Platform::a100_pcie(4), Mesh::flat(4)));
    let mut o = opts("llama-7b", 6, Platform::a100_pcie(4), Mesh::flat(4));
    o.mem_cap = Some((base.plan.mem_bytes as f64 * 0.92) as u64);
    let capped = run_cfp(&o);
    assert!(
        capped.plan.mem_bytes <= o.mem_cap.unwrap()
            || capped.plan.mem_bytes == base.plan.mem_bytes
    );
    assert!(capped.plan.time_us >= base.plan.time_us - 1e-6);
}

#[test]
fn two_node_mesh_produces_inter_node_traffic() {
    let mut o = opts("gpt-2.6b", 4, Platform::a100_two_node(), Mesh { intra: 8, nodes: 2 });
    o.mesh = Mesh { intra: 8, nodes: 2 };
    let r = run_cfp(&o);
    let rep = r.simulate_choice(&o, &r.plan.choice);
    assert!(rep.comm_inter_us > 0.0, "2-node plan must sync gradients across nodes");
}

#[test]
fn zero1_feasible_when_cfp_oom() {
    // Fig. 11's shape: under a cap below CFP's leanest plan, ZeRO-1 still fits
    let r = run_cfp(&opts("llama-7b", 6, Platform::a100_pcie(4), Mesh::flat(4)));
    let z = baselines::zero1_plan(&r.graph, &r.blocks, &r.segments, &r.db, 4, 2.0);
    assert!(z.mem_bytes < r.plan.mem_bytes);
}

#[test]
fn plan_cost_matches_reported_plan() {
    let r = run_cfp(&opts("gpt-2.6b", 4, Platform::a100_pcie(4), Mesh::flat(4)));
    let (t, m) = cost::plan_cost(&r.segments, &r.db, &r.plan.choice);
    assert!((t - r.plan.time_us).abs() < 1e-6);
    assert_eq!(m, r.plan.mem_bytes);
}

#[test]
fn nvlink_prediction_tighter_than_pcie() {
    // Fig. 10: composition error smaller where comm share is smaller
    let mut errs = Vec::new();
    for (platform, mesh) in [
        (Platform::a100_pcie(4), Mesh::flat(4)),
        (Platform::v100_nvlink(), Mesh::flat(4)),
    ] {
        let o = opts("gpt-2.6b", 4, platform, mesh);
        let r = run_cfp(&o);
        let whole = r.simulate_choice(&o, &r.plan.choice).total_us;
        errs.push(((r.plan.time_us - whole) / whole).abs());
    }
    // both predictions within 50%; tight ordering is shape-dependent so we
    // only require sanity here (exact RMSEs live in fig10 driver output)
    assert!(errs.iter().all(|e| *e < 0.5), "{errs:?}");
}
