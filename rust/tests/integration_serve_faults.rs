//! Fault-injection suite for the production serving tier (PR 7):
//!
//! * drain under load — with K leaders held mid-search, a drain closes
//!   admission (a barrage of new requests gets structured `draining`
//!   rejections), yet every already-admitted request completes with the
//!   exact one-shot payload and the admission ledger reconciles. No
//!   accepted request is ever lost.
//! * kill-and-restart — a service with `--cache` + `--plan-cache-file`
//!   is drained and dropped; a fresh service over the same files serves
//!   byte-identical plans with ZERO searches (`searches == 0` and
//!   `search_us == 0`), and those plans equal the cold one-shot CLI
//!   reference.
//! * torn / mismatched / malformed plan-cache files are discarded
//!   wholesale: the restarted service re-searches (correct payloads),
//!   never serves a partially-parsed cache.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use cfp::coordinator::{run_cfp, CfpOptions, PlannerKind};
use cfp::service::{plan_payload, Lifecycle, PlanService, ServeConfig};
use cfp::util::cli::Args;
use cfp::util::Json;

fn plan_line(layers: usize) -> String {
    format!(
        "{{\"id\": \"L{layers}\", \"type\": \"plan\", \"model\": \"gpt-tiny\", \
         \"layers\": {layers}, \"platform\": \"a100-pcie\"}}"
    )
}

fn pipeline_line() -> String {
    "{\"id\": \"pipe\", \"type\": \"pipeline\", \"model\": \"gpt-tiny\", \"layers\": 2, \
     \"microbatches\": 4, \"platform\": \"a100-pcie\"}"
        .to_string()
}

/// The serial one-shot reference for `plan_line(layers)` — the same
/// fields through the same options builder, planned without the service.
fn reference_payload(layers: usize) -> String {
    let mut args = Args::default();
    args.options.insert("model".into(), "gpt-tiny".into());
    args.options.insert("layers".into(), layers.to_string());
    args.options.insert("platform".into(), "a100-pcie".into());
    let built = CfpOptions::from_args(&args, PlannerKind::SingleLevel).unwrap();
    assert!(built.warnings.is_empty());
    plan_payload(&run_cfp(&built.opts)).to_string()
}

fn result_of(resp: &str) -> String {
    let j = Json::parse(resp).expect("response is valid JSON");
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "not ok: {resp}");
    j.get("result").expect("ok response has a result").to_string()
}

fn cache_tag(resp: &str) -> String {
    Json::parse(resp).unwrap().get("cache").unwrap().as_str().unwrap().to_string()
}

/// A scratch directory unique to one test (tests share a process, so
/// the pid alone is not enough).
fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cfp_faults_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn drain_under_load_answers_admitted_work_and_rejects_the_barrage() {
    const LEADERS: usize = 4;
    const BARRAGE: usize = 20;
    let svc = PlanService::new(ServeConfig { workers: LEADERS, ..ServeConfig::default() });

    // Hold every single-flight leader inside its search until the gate
    // opens, so the drain provably begins while work is in flight.
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let entered = Arc::new(AtomicUsize::new(0));
    {
        let gate = Arc::clone(&gate);
        let entered = Arc::clone(&entered);
        svc.set_search_hook(Arc::new(move || {
            entered.fetch_add(1, Ordering::SeqCst);
            let (open, released) = &*gate;
            let mut open = open.lock().unwrap();
            while !*open {
                open = released.wait(open).unwrap();
            }
        }));
    }

    std::thread::scope(|s| {
        // K distinct admitted requests, each leading its own search
        let leaders: Vec<_> = (0..LEADERS)
            .map(|i| {
                let svc = svc.clone();
                s.spawn(move || (2 + i, svc.handle_line(&plan_line(2 + i))))
            })
            .collect();
        while entered.load(Ordering::SeqCst) < LEADERS {
            std::thread::sleep(Duration::from_millis(1));
        }

        // drain while all K searches are mid-flight; it must block until
        // they finish, but close admission immediately
        let drainer = {
            let svc = svc.clone();
            s.spawn(move || svc.drain())
        };
        while svc.lifecycle() != Lifecycle::Draining {
            std::thread::sleep(Duration::from_millis(1));
        }

        // mid-drain barrage: every new request is refused with a
        // structured `draining` rejection, echoing its id
        for i in 0..BARRAGE {
            let resp = svc.handle_line(&plan_line(2 + (i % 8)));
            let j = Json::parse(&resp).unwrap();
            assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false), "{resp}");
            assert_eq!(j.get("reason").and_then(Json::as_str), Some("draining"), "{resp}");
            assert!(j.get("id").is_some(), "rejections still echo the id: {resp}");
        }
        assert_eq!(svc.lifecycle(), Lifecycle::Draining, "still waiting on in-flight work");

        // release the leaders: every admitted request completes with the
        // exact payload the one-shot path produces
        {
            let (open, released) = &*gate;
            *open.lock().unwrap() = true;
            released.notify_all();
        }
        for h in leaders {
            let (layers, resp) = h.join().unwrap();
            assert_eq!(
                result_of(&resp),
                reference_payload(layers),
                "admitted {layers}-layer request must complete correctly through a drain"
            );
        }
        let report = drainer.join().unwrap();
        assert_eq!(svc.lifecycle(), Lifecycle::Drained);

        let s = &report.stats;
        assert_eq!(s.received, (LEADERS + BARRAGE) as u64);
        assert_eq!(s.admitted, LEADERS as u64);
        assert_eq!(s.rejected, BARRAGE as u64);
        assert_eq!(s.rejected_draining, BARRAGE as u64);
        assert_eq!(s.errors, 0, "rejections are not errors");
        assert_eq!(s.received, s.admitted + s.rejected + s.coalesced, "ledger reconciles");
        // the drain report carries the full telemetry picture
        assert!(report.telemetry.latency.contains_key("rejected"));
    });
}

#[test]
fn restart_over_persisted_caches_serves_identical_plans_with_zero_searches() {
    let dir = scratch("restart");
    let cfg = |dir: &std::path::Path| ServeConfig {
        workers: 2,
        cache_path: Some(dir.join("profiles.json")),
        plan_cache_file: Some(dir.join("plans.json")),
        ..ServeConfig::default()
    };
    let lines = [plan_line(2), plan_line(3), pipeline_line()];

    // first life: cold searches, then a clean drain (flushes both caches)
    let first: Vec<String> = {
        let svc = PlanService::new(cfg(&dir));
        let results: Vec<String> =
            lines.iter().map(|l| result_of(&svc.handle_line(l))).collect();
        assert_eq!(svc.stats().searches, 3);
        let report = svc.drain();
        assert_eq!(report.stats.searches, 3);
        results
    }; // service dropped — the "kill"

    // second life over the same files: every request is a warm hit
    let svc = PlanService::new(cfg(&dir));
    for (line, expected) in lines.iter().zip(&first) {
        let resp = svc.handle_line(line);
        assert_eq!(cache_tag(&resp), "hit", "warm restart must not plan: {resp}");
        assert_eq!(&result_of(&resp), expected, "restart must serve byte-identical plans");
    }
    let s = svc.stats();
    assert_eq!(s.searches, 0, "zero searches after a warm restart");
    assert_eq!(s.search_us, 0, "zero µs searching after a warm restart");
    assert_eq!(s.plan_hits, lines.len() as u64);

    // and the persisted plan equals the cold one-shot CLI reference
    assert_eq!(first[0], reference_payload(2));
    svc.drain();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn damaged_plan_cache_files_are_discarded_wholesale() {
    let dir = scratch("torn");
    let plan_file = dir.join("plans.json");
    let cfg = |path: &std::path::Path| ServeConfig {
        workers: 1,
        plan_cache_file: Some(path.to_path_buf()),
        ..ServeConfig::default()
    };

    // seed a valid persisted cache
    let reference = {
        let svc = PlanService::new(cfg(&plan_file));
        let resp = result_of(&svc.handle_line(&plan_line(2)));
        svc.drain();
        resp
    };
    let good = std::fs::read(&plan_file).unwrap();
    assert!(!good.is_empty());

    // a torn file (half-written at crash) must load as nothing: the
    // restarted service re-searches and still serves the right plan
    std::fs::write(&plan_file, &good[..good.len() / 2]).unwrap();
    let svc = PlanService::new(cfg(&plan_file));
    let resp = svc.handle_line(&plan_line(2));
    assert_eq!(cache_tag(&resp), "miss", "torn cache must not warm the service");
    assert_eq!(result_of(&resp), reference);
    assert_eq!(svc.stats().searches, 1);
    svc.drain(); // rewrites a valid file

    // a future/foreign version is discarded wholesale
    std::fs::write(&plan_file, "{\"version\": 99, \"clock\": 1, \"plans\": []}").unwrap();
    let svc = PlanService::new(cfg(&plan_file));
    assert_eq!(cache_tag(&svc.handle_line(&plan_line(2))), "miss");
    svc.drain();

    // ONE malformed entry poisons the whole file — no partial loads
    std::fs::write(
        &plan_file,
        "{\"version\": 1, \"clock\": 3, \"plans\": [{\"key\": \"k\", \"stamp\": 1, \
         \"payload\": 42}]}",
    )
    .unwrap();
    let svc = PlanService::new(cfg(&plan_file));
    let resp = svc.handle_line(&plan_line(2));
    assert_eq!(cache_tag(&resp), "miss", "malformed entry must discard the whole cache");
    assert_eq!(result_of(&resp), reference);
    svc.drain();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncation_at_every_byte_boundary_discards_wholesale_and_replans_identically() {
    let dir = scratch("boundary");
    let plan_file = dir.join("plans.json");
    let cfg = |path: &std::path::Path| ServeConfig {
        workers: 1,
        plan_cache_file: Some(path.to_path_buf()),
        ..ServeConfig::default()
    };

    // seed a known-good persisted cache and its served payload
    let reference = {
        let svc = PlanService::new(cfg(&plan_file));
        let resp = result_of(&svc.handle_line(&plan_line(2)));
        svc.drain();
        resp
    };
    let good = std::fs::read(&plan_file).unwrap();
    assert!(good.len() > 2, "seeded cache file is non-trivial");

    // a torn write can stop after ANY byte; every proper prefix must be
    // refused outright at the loader — no partial parses, ever
    for cut in 0..good.len() {
        std::fs::write(&plan_file, &good[..cut]).unwrap();
        assert!(
            cfp::service::plancache::load(&plan_file).is_none(),
            "prefix of {cut}/{} bytes must not load",
            good.len()
        );
    }

    // sampled cuts drive a full service restart: the damaged file costs
    // exactly one re-search and the re-served plan is byte-identical
    for cut in [0, 1, good.len() / 3, good.len() / 2, good.len() - 1] {
        std::fs::write(&plan_file, &good[..cut]).unwrap();
        let svc = PlanService::new(cfg(&plan_file));
        let resp = svc.handle_line(&plan_line(2));
        assert_eq!(cache_tag(&resp), "miss", "cut at {cut} must cold-start the service");
        assert_eq!(result_of(&resp), reference, "re-search after cut at {cut}");
        assert_eq!(svc.stats().searches, 1);
        svc.drain(); // rewrites a valid file; next iteration re-damages it
    }
    let _ = std::fs::remove_dir_all(&dir);
}
