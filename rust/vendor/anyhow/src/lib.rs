//! Minimal vendored replacement for the `anyhow` crate.
//!
//! The external vendor set is empty in this build, so the subset of the
//! anyhow API the repo actually uses is reimplemented here: [`Error`],
//! [`Result`], the [`anyhow!`] macro, and the [`Context`] extension trait
//! (on both `Result` and `Option`). Errors carry a single flattened
//! message string — backtraces and error chains are out of scope.

use std::fmt;

/// A flattened error message (the vendored stand-in for `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prefix the error with higher-level context.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow's blanket conversion; `Error` itself deliberately does
// NOT implement `std::error::Error`, which keeps this impl coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result` errors or `None` options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(e.to_string(), "bad thing at 7");
        assert_eq!(format!("{e:?}"), "bad thing at 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<()> = io_err().context("opening manifest");
        assert_eq!(r.unwrap_err().to_string(), "opening manifest: gone");
        let o: Result<i32> = None.with_context(|| format!("missing {}", "flops"));
        assert_eq!(o.unwrap_err().to_string(), "missing flops");
        let some: Result<i32> = Some(3).context("unused");
        assert_eq!(some.unwrap(), 3);
    }
}
