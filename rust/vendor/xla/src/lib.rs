//! Typed stub of the `xla` PJRT bindings.
//!
//! The real crate wraps libxla's PJRT CPU client. This vendored stub keeps
//! the exact type/method surface the repo compiles against, with host-side
//! [`Literal`] construction fully functional (used by `runtime::random_*`
//! and the trainer's input packing) and every device-side operation —
//! client creation, HLO parsing, compilation, execution — returning a
//! descriptive error. `runtime::Runtime::open*` therefore fails fast and
//! all callers take their existing "no artifacts / no PJRT" skip paths.

use std::fmt;
use std::path::Path;

/// Error type mirroring the bindings' debug-printable error.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT backend unavailable (vendored xla stub — link the real \
         xla crate to execute artifacts)"
    ))
}

/// Element types a [`Literal`] can hold host-side (public because the
/// [`NativeType`] trait mentions it in its method signatures).
#[derive(Clone, Debug)]
#[doc(hidden)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor literal (fully functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Rust scalar types storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    fn to_data(v: &[Self]) -> Data;
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_data(v: &[f32]) -> Data {
        Data::F32(v.to_vec())
    }
    fn from_data(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn to_data(v: &[i32]) -> Data {
        Data::I32(v.to_vec())
    }
    fn from_data(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::to_data(v), dims: vec![v.len() as i64] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret the literal under new dimensions (element count must
    /// match; an empty dims list is a scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(XlaError(format!(
                "reshape: cannot view {have} elements as {dims:?}"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data)
            .ok_or_else(|| XlaError("to_vec: element type mismatch".to_string()))
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(t) => Ok(t),
            data => Ok(vec![Literal { data, dims: self.dims }]),
        }
    }
}

/// PJRT client handle. `cpu()` always errors in the stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[0.5f32]).reshape(&[]).unwrap();
        assert_eq!(l.element_count(), 1);
        assert!(l.dims().is_empty());
    }

    #[test]
    fn device_paths_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
