//! Bench: one full Fig. 7 cell (CFP + three baselines on one model ×
//! platform) — the end-to-end evaluation kernel. §Perf target: the whole
//! 4×4 Fig. 7 sweep under 2 minutes ⇒ a cell well under 8 s.

use std::time::Duration;

use cfp::cluster::Platform;
use cfp::harness::throughput_row;
use cfp::models::ModelCfg;
use cfp::spmd::Mesh;
use cfp::util::bench::{bench, black_box};

fn main() {
    for preset in ["gpt-2.6b", "moe-7.1b"] {
        let model = ModelCfg::preset(preset).with_layers(4).with_batch(8).scaled_for_eval();
        bench(
            &format!("fig7_cell/{preset}/a100-pcie-4"),
            Duration::from_secs(3),
            || {
                let (row, _) = throughput_row(&model, Platform::a100_pcie(4), Mesh::flat(4));
                black_box(row.cfp_us);
            },
        );
    }
}
