//! Bench: `cfp serve` warm-path economics (ISSUE 4 acceptance).
//!
//! * cold — a fresh service per request: full AnalysisPasses +
//!   MetricsProfiling + ComposeSearch, the one-shot CLI economics
//! * profile-warm — plan cache disabled, shared profile cache warm: the
//!   search re-runs but MetricsProfiling is a lookup
//! * plan-warm — plan cache hit: no planning at all
//! * coalescing — N concurrent identical requests perform exactly one
//!   search (leader held until every follower registers)
//! * mixed 10k — 10 000 warm requests over 8 model×layers variants,
//!   both in-process and over loopback TCP; p50/p99/throughput land in
//!   `BENCH_serve.json` (via `merge_bench_json`, so `cfp bench-serve`
//!   rows and these coexist)
//!
//! Acceptance: warm (either warm path's best) ≥ 10× faster than cold.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use cfp::service::{PlanService, ServeConfig};
use cfp::util::bench::{bench, black_box, merge_bench_json, JsonRow};
use cfp::util::Json;

fn line(layers: usize) -> String {
    format!(
        "{{\"type\": \"plan\", \"model\": \"gpt-tiny\", \"layers\": {layers}, \
         \"platform\": \"a100-pcie\"}}"
    )
}

/// Request `i` of the mixed-model stream: alternating gpt-tiny/moe-tiny
/// over layers 2–5, so `i % 8` picks one of 8 distinct plan keys.
fn mixed_line(i: usize) -> String {
    let model = ["gpt-tiny", "moe-tiny"][i % 2];
    let layers = 2 + (i / 2) % 4;
    format!(
        "{{\"id\": {i}, \"type\": \"plan\", \"model\": \"{model}\", \"layers\": {layers}, \
         \"platform\": \"a100-pcie\", \"client\": \"bench\"}}"
    )
}

/// Sort one lane's latencies, print the distribution, and stage
/// p50/p99/throughput rows for `BENCH_serve.json`.
fn lane_rows(mode: &str, mut lat_us: Vec<f64>, wall: f64, rows: &mut Vec<JsonRow>) {
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = lat_us.len();
    let q = |p: usize| lat_us[(n - 1) * p / 100];
    let thr = n as f64 / wall.max(1e-9);
    println!(
        "bench serve/mixed10k_{mode}: {n} requests in {:.3}s — \
         p50 {:.1}µs  p99 {:.1}µs  max {:.1}µs  ({thr:.0} req/s)",
        wall,
        q(50),
        q(99),
        lat_us[n - 1],
    );
    for (metric, value, unit) in
        [("p50_us", q(50), "us"), ("p99_us", q(99), "us"), ("throughput", thr, "req_per_s")]
    {
        rows.push(JsonRow {
            name: format!("serve/mixed10k_{mode}/{metric}"),
            layers: n,
            ns_per_iter: value,
            unit: Some(unit),
            speedup: None,
        });
    }
}

fn main() {
    // cold: a fresh service (empty caches) per request
    let cold_s = {
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            let svc = PlanService::new(ServeConfig::default());
            black_box(svc.handle_line(&line(2)));
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    println!("bench serve/cold_fresh_service: {:.3}ms per request", cold_s * 1e3);

    // plan-warm: the LRU plan cache answers without planning
    let svc = PlanService::new(ServeConfig::default());
    svc.handle_line(&line(2));
    let plan_warm = bench("serve/warm_plan_cache_hit", Duration::from_millis(300), || {
        black_box(svc.handle_line(&line(2)));
    });

    // profile-warm: plan cache disabled, so every request re-plans, but
    // the shared profile cache turns MetricsProfiling into lookups
    let svc2 = PlanService::new(ServeConfig { plan_cache_entries: 0, ..ServeConfig::default() });
    svc2.handle_line(&line(2));
    let profile_warm = bench("serve/warm_profile_cache", Duration::from_millis(500), || {
        black_box(svc2.handle_line(&line(2)));
    });

    let plan_speedup = cold_s * 1e9 / plan_warm.median_ns;
    let profile_speedup = cold_s * 1e9 / profile_warm.median_ns;
    println!(
        "warm/cold speedup: plan-cache {plan_speedup:.0}x, profile-cache {profile_speedup:.1}x"
    );
    assert!(
        plan_speedup >= 10.0,
        "acceptance: warm requests must be ≥ 10x faster than cold \
         (measured {plan_speedup:.1}x)"
    );

    // coalescing efficiency: N concurrent identical requests → 1 search
    const N: usize = 8;
    let svc3 = PlanService::new(ServeConfig {
        plan_cache_entries: 0,
        workers: N,
        ..ServeConfig::default()
    });
    let probe = svc3.clone();
    svc3.set_search_hook(Arc::new(move || {
        while probe.stats().coalesced < (N as u64) - 1 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }));
    let start = Arc::new(Barrier::new(N));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..N {
            let svc3 = svc3.clone();
            let start = Arc::clone(&start);
            s.spawn(move || {
                start.wait();
                black_box(svc3.handle_line(&line(3)));
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = svc3.stats();
    println!(
        "bench serve/coalescing: {N} identical concurrent requests in {:.3}ms — \
         searches {}, coalesced {}",
        wall * 1e3,
        stats.searches,
        stats.coalesced
    );
    assert_eq!(stats.searches, 1, "single-flight must run exactly one search");
    assert_eq!(stats.coalesced as usize, N - 1);

    // sanity: the served payload is identical whichever path produced it
    let a = svc.handle_line(&line(2));
    let b = svc2.handle_line(&line(2));
    let pa = Json::parse(&a).unwrap().get("result").unwrap().to_string();
    let pb = Json::parse(&b).unwrap().get("result").unwrap().to_string();
    assert_eq!(pa, pb, "plan-warm and profile-warm payloads are bit-identical");

    // mixed 10k: 10 000 warm requests over 8 model×layers variants,
    // first in-process (16 threads calling handle_line), then the same
    // stream over loopback TCP in request/response lockstep per
    // connection. Warm hits are cheap, so this lane runs in full even
    // under CFP_BENCH_SMOKE.
    const TOTAL: usize = 10_000;
    const THREADS: usize = 16;
    let svc4 = PlanService::new(ServeConfig { workers: THREADS, ..ServeConfig::default() });
    for i in 0..8 {
        let resp = svc4.handle_line(&mixed_line(i));
        let j = Json::parse(&resp).expect("pre-warm response is JSON");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "pre-warm failed: {resp}");
    }
    let mut rows: Vec<JsonRow> = Vec::new();

    let t0 = Instant::now();
    let lat: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let svc = svc4.clone();
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(TOTAL / THREADS + 1);
                    let mut i = t;
                    while i < TOTAL {
                        let q0 = Instant::now();
                        black_box(svc.handle_line(&mixed_line(i)));
                        lat.push(q0.elapsed().as_secs_f64() * 1e6);
                        i += THREADS;
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    lane_rows("inproc", lat, t0.elapsed().as_secs_f64(), &mut rows);

    match svc4.listen("127.0.0.1:0") {
        Ok(addr) => {
            let t0 = Instant::now();
            let lat: Vec<f64> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..THREADS)
                    .map(|t| {
                        s.spawn(move || {
                            let stream = TcpStream::connect(addr).expect("connect loopback");
                            let mut reader =
                                BufReader::new(stream.try_clone().expect("clone tcp stream"));
                            let mut w = stream;
                            let mut lat = Vec::with_capacity(TOTAL / THREADS + 1);
                            let mut resp = String::new();
                            let mut i = t;
                            while i < TOTAL {
                                let q0 = Instant::now();
                                writeln!(w, "{}", mixed_line(i)).expect("write request");
                                resp.clear();
                                reader.read_line(&mut resp).expect("read response");
                                lat.push(q0.elapsed().as_secs_f64() * 1e6);
                                if i % 97 == 0 {
                                    let j = Json::parse(&resp).expect("tcp response is JSON");
                                    assert_eq!(
                                        j.get("ok").and_then(Json::as_bool),
                                        Some(true),
                                        "tcp response not ok: {resp}"
                                    );
                                }
                                i += THREADS;
                            }
                            lat
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });
            lane_rows("tcp", lat, t0.elapsed().as_secs_f64(), &mut rows);
        }
        Err(e) => eprintln!("bench serve: tcp lane skipped: {e}"),
    }

    let report = svc4.drain();
    let s = svc4.stats();
    assert_eq!(s.searches, 8, "every mixed-model request after pre-warm must be a cache hit");
    assert_eq!(s.received, s.admitted + s.rejected + s.coalesced, "admission ledger reconciles");
    println!("{}", report.summary_line());

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_serve.json");
    match merge_bench_json(&path, &rows) {
        Ok(()) => println!("bench rows updated in {}", path.display()),
        Err(e) => eprintln!("bench serve: could not write {}: {e}", path.display()),
    }
}
