//! Bench: `cfp serve` warm-path economics (ISSUE 4 acceptance).
//!
//! * cold — a fresh service per request: full AnalysisPasses +
//!   MetricsProfiling + ComposeSearch, the one-shot CLI economics
//! * profile-warm — plan cache disabled, shared profile cache warm: the
//!   search re-runs but MetricsProfiling is a lookup
//! * plan-warm — plan cache hit: no planning at all
//! * coalescing — N concurrent identical requests perform exactly one
//!   search (leader held until every follower registers)
//!
//! Acceptance: warm (either warm path's best) ≥ 10× faster than cold.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use cfp::service::{PlanService, ServeConfig};
use cfp::util::bench::{bench, black_box};
use cfp::util::Json;

fn line(layers: usize) -> String {
    format!(
        "{{\"type\": \"plan\", \"model\": \"gpt-tiny\", \"layers\": {layers}, \
         \"platform\": \"a100-pcie\"}}"
    )
}

fn main() {
    // cold: a fresh service (empty caches) per request
    let cold_s = {
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            let svc = PlanService::new(ServeConfig::default());
            black_box(svc.handle_line(&line(2)));
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    println!("bench serve/cold_fresh_service: {:.3}ms per request", cold_s * 1e3);

    // plan-warm: the LRU plan cache answers without planning
    let svc = PlanService::new(ServeConfig::default());
    svc.handle_line(&line(2));
    let plan_warm = bench("serve/warm_plan_cache_hit", Duration::from_millis(300), || {
        black_box(svc.handle_line(&line(2)));
    });

    // profile-warm: plan cache disabled, so every request re-plans, but
    // the shared profile cache turns MetricsProfiling into lookups
    let svc2 = PlanService::new(ServeConfig { plan_cache_entries: 0, ..ServeConfig::default() });
    svc2.handle_line(&line(2));
    let profile_warm = bench("serve/warm_profile_cache", Duration::from_millis(500), || {
        black_box(svc2.handle_line(&line(2)));
    });

    let plan_speedup = cold_s * 1e9 / plan_warm.median_ns;
    let profile_speedup = cold_s * 1e9 / profile_warm.median_ns;
    println!(
        "warm/cold speedup: plan-cache {plan_speedup:.0}x, profile-cache {profile_speedup:.1}x"
    );
    assert!(
        plan_speedup >= 10.0,
        "acceptance: warm requests must be ≥ 10x faster than cold \
         (measured {plan_speedup:.1}x)"
    );

    // coalescing efficiency: N concurrent identical requests → 1 search
    const N: usize = 8;
    let svc3 = PlanService::new(ServeConfig {
        plan_cache_entries: 0,
        workers: N,
        ..ServeConfig::default()
    });
    let probe = svc3.clone();
    svc3.set_search_hook(Arc::new(move || {
        while probe.stats().coalesced < (N as u64) - 1 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }));
    let start = Arc::new(Barrier::new(N));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..N {
            let svc3 = svc3.clone();
            let start = Arc::clone(&start);
            s.spawn(move || {
                start.wait();
                black_box(svc3.handle_line(&line(3)));
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = svc3.stats();
    println!(
        "bench serve/coalescing: {N} identical concurrent requests in {:.3}ms — \
         searches {}, coalesced {}",
        wall * 1e3,
        stats.searches,
        stats.coalesced
    );
    assert_eq!(stats.searches, 1, "single-flight must run exactly one search");
    assert_eq!(stats.coalesced as usize, N - 1);

    // sanity: the served payload is identical whichever path produced it
    let a = svc.handle_line(&line(2));
    let b = svc2.handle_line(&line(2));
    let pa = Json::parse(&a).unwrap().get("result").unwrap().to_string();
    let pb = Json::parse(&b).unwrap().get("result").unwrap().to_string();
    assert_eq!(pa, pb, "plan-warm and profile-warm payloads are bit-identical");
}
