//! Bench: ComposeSearch (Eq. 8/9 Pareto DP) vs depth and memory caps —
//! Fig. 13 right-hand scaling. §Perf target: 32-layer GPT < 1 s.

use std::time::Duration;

use cfp::cluster::Platform;
use cfp::cost;
use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::profiler::{profile_model, ProfileOptions};
use cfp::segment::extract_segments;
use cfp::spmd::Mesh;
use cfp::util::bench::{bench, black_box};

fn main() {
    for layers in [4usize, 16, 32] {
        let cfg = ModelCfg::preset("gpt-2.6b").with_layers(layers).scaled_for_eval();
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        let free = cost::search(&ss, &db, None).unwrap();
        bench(
            &format!("compose_search/unconstrained/{layers}L"),
            Duration::from_millis(700),
            || {
                black_box(cost::search(&ss, &db, None));
            },
        );
        let cap = (free.mem_bytes as f64 * 0.9) as u64;
        bench(
            &format!("compose_search/mem_capped/{layers}L"),
            Duration::from_millis(700),
            || {
                black_box(cost::search(&ss, &db, Some(cap)));
            },
        );
        bench(
            &format!("search_uniform/serial/{layers}L"),
            Duration::from_millis(700),
            || {
                black_box(cost::search_uniform(&ss, &db, None));
            },
        );
        bench(
            &format!("search_uniform/threads=4/{layers}L"),
            Duration::from_millis(700),
            || {
                black_box(cost::search_uniform_with(&ss, &db, None, 4));
            },
        );
    }

    // brute force needs a tiny instance count to stay exponential-but-sane
    let cfg = ModelCfg::preset("gpt-tiny").with_layers(2);
    let g = build_training(&cfg);
    let bs = build_parallel_blocks(&g, 4);
    let ss = extract_segments(&g, &bs);
    let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    let db = profile_model(&g, &bs, &ss, &opts);
    bench("brute_force/serial/gpt-tiny-2L", Duration::from_secs(2), || {
        black_box(cost::brute_force(&ss, &db, None));
    });
    bench("brute_force/threads=4/gpt-tiny-2L", Duration::from_secs(2), || {
        black_box(cost::brute_force_with(&ss, &db, None, 4));
    });
}
