//! Bench: ComposeSearch (Eq. 8/9 Pareto DP) vs depth and memory caps —
//! Fig. 13 right-hand scaling. §Perf target: 32-layer GPT < 1 s; the
//! 512-layer unconstrained chain DP ≥ 10× the pre-refactor reference
//! (recorded in `BENCH_search.json` at the repo root).
//!
//! Modes:
//! * default — full sweep: the classic 4/16/32-layer section, the
//!   repetition-aware chain scaling section (32/128/512 layers, new DP
//!   vs the [`cfp::cost::oracle`] reference), and the brute-force
//!   parallelism section. Rows land in `BENCH_search.json`.
//! * `CFP_BENCH_SMOKE=1` — CI regression tripwire: only the 32-layer
//!   chain, short budgets, and a hard failure if the unconstrained
//!   search exceeds a generous wall-clock ceiling.

use std::time::Duration;

use cfp::cluster::Platform;
use cfp::cost;
use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::profiler::{profile_model, ProfileDb, ProfileOptions};
use cfp::segment::{extract_segments, SegmentSet};
use cfp::spmd::Mesh;
use cfp::util::bench::{bench, black_box, merge_bench_json, JsonRow};

/// Generous CI ceiling for one 32-layer unconstrained search (the §Perf
/// target is < 1 s for the whole pipeline; the DP alone at 32 layers
/// runs in well under a millisecond — 250 ms only catches catastrophic
/// regressions, not noise).
const SMOKE_CEILING_NS: f64 = 250e6;

fn setup(layers: usize) -> (SegmentSet, ProfileDb) {
    let cfg = ModelCfg::preset("gpt-2.6b").with_layers(layers).scaled_for_eval();
    let g = build_training(&cfg);
    let bs = build_parallel_blocks(&g, 4);
    let ss = extract_segments(&g, &bs);
    let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
    let db = profile_model(&g, &bs, &ss, &opts);
    (ss, db)
}

fn main() {
    let smoke = std::env::var("CFP_BENCH_SMOKE").is_ok();
    let mut rows: Vec<JsonRow> = Vec::new();

    if !smoke {
        for layers in [4usize, 16, 32] {
            let (ss, db) = setup(layers);
            let free = cost::search(&ss, &db, None).unwrap();
            let r = bench(
                &format!("compose_search/unconstrained/{layers}L"),
                Duration::from_millis(700),
                || {
                    black_box(cost::search(&ss, &db, None));
                },
            );
            rows.push(JsonRow {
                name: r.name.clone(),
                layers,
                ns_per_iter: r.median_ns,
                unit: None,
                speedup: None,
            });
            let cap = (free.mem_bytes as f64 * 0.9) as u64;
            let r = bench(
                &format!("compose_search/mem_capped/{layers}L"),
                Duration::from_millis(700),
                || {
                    black_box(cost::search(&ss, &db, Some(cap)));
                },
            );
            rows.push(JsonRow {
                name: r.name.clone(),
                layers,
                ns_per_iter: r.median_ns,
                unit: None,
                speedup: None,
            });
            bench(
                &format!("search_uniform/serial/{layers}L"),
                Duration::from_millis(700),
                || {
                    black_box(cost::search_uniform(&ss, &db, None));
                },
            );
            bench(
                &format!("search_uniform/threads=4/{layers}L"),
                Duration::from_millis(700),
                || {
                    black_box(cost::search_uniform_with(&ss, &db, None, 4));
                },
            );
        }
    }

    // chain-DP scaling: the repetition-aware search vs the pre-refactor
    // per-position Pareto DP, on deep chains of one repeated layer — the
    // regime the steady-state splice and SearchCtx flat transitions are
    // built for. Acceptance: ≥ 10× at 512 layers.
    let depths: &[usize] = if smoke { &[32] } else { &[32, 128, 512] };
    let mut smoke_breach = false;
    for &layers in depths {
        let (ss, db) = setup(layers);
        let n = ss.instances.len();
        // sanity: both paths agree before we time them
        let new_plan = cost::search(&ss, &db, None).expect("plan");
        let ref_plan = cost::oracle::search_span_reference(&ss, &db, None, 0, n).expect("plan");
        assert!(
            new_plan.time_us.to_bits() == ref_plan.time_us.to_bits()
                && new_plan.choice == ref_plan.choice,
            "{layers}L: repetition-aware DP diverged from the reference"
        );
        let budget = Duration::from_millis(if smoke { 150 } else { 600 });
        let new = bench(&format!("chain_dp/new/{layers}L"), budget, || {
            black_box(cost::search(&ss, &db, None));
        });
        let reference = bench(&format!("chain_dp/oracle/{layers}L"), budget, || {
            black_box(cost::oracle::search_span_reference(&ss, &db, None, 0, n));
        });
        let speedup = reference.median_ns / new.median_ns.max(1e-9);
        println!(
            "chain_dp/{layers}L: {:.1}x vs pre-refactor reference",
            speedup
        );
        rows.push(JsonRow {
            name: format!("chain_dp/new/{layers}L"),
            layers,
            ns_per_iter: new.median_ns,
            unit: None,
            speedup: Some(speedup),
        });
        rows.push(JsonRow {
            name: format!("chain_dp/oracle/{layers}L"),
            layers,
            ns_per_iter: reference.median_ns,
            unit: None,
            speedup: None,
        });
        if smoke && layers == 32 && new.median_ns > SMOKE_CEILING_NS {
            eprintln!(
                "PERF SMOKE FAILURE: 32-layer unconstrained search took {:.1} ms/iter \
                 (ceiling {:.0} ms)",
                new.median_ns / 1e6,
                SMOKE_CEILING_NS / 1e6
            );
            smoke_breach = true;
        }
    }

    if !smoke {
        // brute force needs a tiny instance count to stay exponential-but-sane
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(2);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        bench("brute_force/serial/gpt-tiny-2L", Duration::from_secs(2), || {
            black_box(cost::brute_force(&ss, &db, None));
        });
        bench("brute_force/threads=4/gpt-tiny-2L", Duration::from_secs(2), || {
            black_box(cost::brute_force_with(&ss, &db, None, 4));
        });
    }

    // exact-vs-DP lane (PR 6): the branch-and-bound optimality oracle on
    // a small synthetic chain, priced against the production DP it
    // certifies. The ratio is the cost of certification, not a target —
    // the DP must win; the lane exists so BENCH trajectories notice if
    // the exact lane's pruning regresses into the un-benchable.
    {
        let (ss, db) = cfp::harness::synthetic_chain(10, 3, 3, 0xE5AC7);
        let n = ss.instances.len();
        let sctx = cost::SearchCtx::new(&ss, &db);
        let dp_plan = cost::search_span_ctx(&sctx, None, 0, n).expect("plan");
        let ex_plan = cost::search_span_exact(&sctx, None, 0, n).expect("plan");
        assert!(
            dp_plan.time_us.to_bits() == ex_plan.time_us.to_bits(),
            "exact lane diverged from the DP on the bench instance"
        );
        let budget = Duration::from_millis(if smoke { 100 } else { 400 });
        let dp = bench(&format!("exact_bnb/dp/{n}n"), budget, || {
            black_box(cost::search_span_ctx(&sctx, None, 0, n));
        });
        let ex = bench(&format!("exact_bnb/bnb/{n}n"), budget, || {
            black_box(cost::search_span_exact(&sctx, None, 0, n));
        });
        let ratio = ex.median_ns / dp.median_ns.max(1e-9);
        println!("exact_bnb/{n}n: exact costs {ratio:.1}x the DP (certification overhead)");
        rows.push(JsonRow {
            name: format!("exact_bnb/dp/{n}n"),
            layers: n,
            ns_per_iter: dp.median_ns,
            unit: None,
            speedup: None,
        });
        rows.push(JsonRow {
            name: format!("exact_bnb/bnb/{n}n"),
            layers: n,
            ns_per_iter: ex.median_ns,
            unit: None,
            speedup: Some(ratio),
        });
    }

    // sp-dag lane (PR 8): the series-parallel DP vs the plain chain DP
    // on identical per-instance data — `synthetic_spdag` derives its
    // profiles from `synthetic_chain` with the same seed, so the two
    // searches price the same numbers and differ only in topology. The
    // ratio is the cost of fork/merge junction pricing and the recursive
    // SP decomposition, not a target; the exact row prices the
    // branch-and-bound certification lane on the same instance.
    {
        let (ss, db, topo) = cfp::harness::synthetic_spdag(1, 2, 3, 2, 3, 3, 0x59DA6);
        let n = ss.instances.len();
        let sctx = cost::SearchCtx::new(&ss, &db);
        let sp = cfp::spdag::SpCtx::new(&sctx, &topo, &db);
        let (css, cdb) = cfp::harness::synthetic_chain(n, 3, 3, 0x59DA6);
        let cctx = cost::SearchCtx::new(&css, &cdb);
        // sanity: the DAG DP and the exact lane agree before we time them
        let dp_plan = cfp::spdag::sp_search_span(&sctx, &sp, None, 0, n).expect("plan");
        let ex_plan = cfp::spdag::sp_search_span_exact(&sctx, &sp, None, 0, n).expect("plan");
        assert!(
            dp_plan.time_us.to_bits() == ex_plan.time_us.to_bits(),
            "sp-dag exact lane diverged from the DP on the bench instance"
        );
        let budget = Duration::from_millis(if smoke { 100 } else { 400 });
        let chain = bench(&format!("spdag/chain_dp/{n}n"), budget, || {
            black_box(cost::search_span_ctx(&cctx, None, 0, n));
        });
        let dag = bench(&format!("spdag/sp_dp/{n}n"), budget, || {
            black_box(cfp::spdag::sp_search_span(&sctx, &sp, None, 0, n));
        });
        let overhead = dag.median_ns / chain.median_ns.max(1e-9);
        println!("spdag/{n}n: DAG DP costs {overhead:.1}x the chain DP on identical data");
        rows.push(JsonRow {
            name: format!("spdag/chain_dp/{n}n"),
            layers: n,
            ns_per_iter: chain.median_ns,
            unit: None,
            speedup: None,
        });
        rows.push(JsonRow {
            name: format!("spdag/sp_dp/{n}n"),
            layers: n,
            ns_per_iter: dag.median_ns,
            unit: None,
            speedup: Some(overhead),
        });
        let ex = bench(&format!("spdag/exact/{n}n"), budget, || {
            black_box(cfp::spdag::sp_search_span_exact(&sctx, &sp, None, 0, n));
        });
        rows.push(JsonRow {
            name: format!("spdag/exact/{n}n"),
            layers: n,
            ns_per_iter: ex.median_ns,
            unit: None,
            speedup: Some(ex.median_ns / dag.median_ns.max(1e-9)),
        });

        // expert-parallel MoE presets: the sp search priced on real
        // preset artifacts (graph → segments → profiles via run_cfp)
        if !smoke {
            use cfp::coordinator::{run_cfp, CfpOptions};
            let presets = [
                ModelCfg::preset("moe-ep-tiny").with_layers(4),
                ModelCfg::preset("moe-ep-7.1b").with_layers(4).with_batch(8).scaled_for_eval(),
            ];
            for model in presets {
                let name = model.name.clone();
                let layers = model.layers;
                let opts = CfpOptions::new(model, Platform::a100_pcie(4));
                let r = run_cfp(&opts);
                assert!(!r.topo.is_chain(), "{name}: expert branches make an SP-DAG");
                let sctx = cost::SearchCtx::new(&r.segments, &r.db);
                let sp = cfp::spdag::SpCtx::new(&sctx, &r.topo, &r.db);
                let pn = r.segments.instances.len();
                let pr = bench(
                    &format!("spdag/preset/{name}"),
                    Duration::from_millis(400),
                    || {
                        black_box(cfp::spdag::sp_search_span(&sctx, &sp, None, 0, pn));
                    },
                );
                rows.push(JsonRow {
                    name: pr.name.clone(),
                    layers,
                    ns_per_iter: pr.median_ns,
                    unit: None,
                    speedup: None,
                });
            }
        }
    }

    // trace overhead lane (PR 9): the same 32-layer unconstrained chain
    // search with the obs trace disabled vs enabled. The acceptance bar
    // is ≤ 1% overhead when disabled is compared against itself across
    // runs; here we record the enabled/disabled ratio so BENCH
    // trajectories notice if counter flushes creep into hot loops. Runs
    // in smoke so CI uploads the row every cycle; no hard assert — the
    // ratio is noise-prone at sub-millisecond iteration times.
    {
        let layers = 32usize;
        let (ss, db) = setup(layers);
        let n = ss.instances.len();
        let off_ctx = cost::SearchCtx::new(&ss, &db);
        let on_ctx = cost::SearchCtx::with_trace(&ss, &db, cfp::obs::Trace::enabled());
        let off_plan = cost::search_span_ctx(&off_ctx, None, 0, n).expect("plan");
        let on_plan = cost::search_span_ctx(&on_ctx, None, 0, n).expect("plan");
        assert!(
            off_plan.time_us.to_bits() == on_plan.time_us.to_bits()
                && off_plan.choice == on_plan.choice,
            "tracing changed the plan"
        );
        let budget = Duration::from_millis(if smoke { 100 } else { 400 });
        let off = bench(&format!("trace_overhead/off/{layers}L"), budget, || {
            black_box(cost::search_span_ctx(&off_ctx, None, 0, n));
        });
        let on = bench(&format!("trace_overhead/on/{layers}L"), budget, || {
            black_box(cost::search_span_ctx(&on_ctx, None, 0, n));
        });
        let ratio = on.median_ns / off.median_ns.max(1e-9);
        println!("trace_overhead/{layers}L: enabled costs {ratio:.3}x the disabled search");
        rows.push(JsonRow {
            name: format!("trace_overhead/off/{layers}L"),
            layers,
            ns_per_iter: off.median_ns,
            unit: None,
            speedup: None,
        });
        rows.push(JsonRow {
            name: format!("trace_overhead/on/{layers}L"),
            layers,
            ns_per_iter: on.median_ns,
            unit: None,
            speedup: Some(ratio),
        });
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_search.json");
    match merge_bench_json(&path, &rows) {
        Ok(()) => println!("wrote {} rows to {}", rows.len(), path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    if smoke_breach {
        std::process::exit(1);
    }
}
