//! Bench: the memory axis of the search — rematerialization frontier
//! construction and the enlarged (config × remat) span DP — vs the plain
//! span DP and vs the pre-refactor reference implementation, so the
//! search-time cost of making memory a searched quantity is tracked.
//! §Perf target: the memory DP stays within ~2–4× of the plain span
//! search at equal depth. Rows land in `BENCH_search.json` (shared with
//! the search bench; rows merge by name).

use std::time::Duration;

use cfp::cluster::Platform;
use cfp::cost;
use cfp::memory::{self, RecomputeSpec};
use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::profiler::{profile_model, ProfileOptions};
use cfp::segment::extract_segments;
use cfp::spmd::Mesh;
use cfp::util::bench::{bench, black_box, merge_bench_json, JsonRow};

fn main() {
    let mut rows: Vec<JsonRow> = Vec::new();
    for layers in [4usize, 8, 16] {
        let cfg = ModelCfg::preset("gpt-2.6b").with_layers(layers).scaled_for_eval();
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        let n = ss.instances.len();

        // baseline: the plain span DP (repetition-aware since PR 5)
        bench(
            &format!("span_search/plain/{layers}L"),
            Duration::from_millis(500),
            || {
                black_box(cost::search_span(&ss, &db, None, 0, n));
            },
        );
        // the enlarged DP, recompute off (2× state from the frontier form)
        bench(
            &format!("span_search/mem_frontier_off/{layers}L"),
            Duration::from_millis(500),
            || {
                black_box(cost::search_span_mem(&ss, &db, 0, n, RecomputeSpec::Off));
            },
        );
        // the full memory axis: per-instance keep-vs-checkpoint choices,
        // new hoisted-transition DP vs the pre-refactor reference
        let auto_ = bench(
            &format!("span_search/mem_frontier_auto/{layers}L"),
            Duration::from_millis(500),
            || {
                black_box(cost::search_span_mem(&ss, &db, 0, n, RecomputeSpec::Auto));
            },
        );
        let reference = bench(
            &format!("span_search/mem_frontier_oracle/{layers}L"),
            Duration::from_millis(500),
            || {
                black_box(cost::oracle::search_span_mem_reference(
                    &ss,
                    &db,
                    0,
                    n,
                    RecomputeSpec::Auto,
                ));
            },
        );
        rows.push(JsonRow {
            name: format!("span_search/mem_frontier_auto/{layers}L"),
            layers,
            ns_per_iter: auto_.median_ns,
            unit: None,
            speedup: Some(reference.median_ns / auto_.median_ns.max(1e-9)),
        });
        rows.push(JsonRow {
            name: format!("span_search/mem_frontier_oracle/{layers}L"),
            layers,
            ns_per_iter: reference.median_ns,
            unit: None,
            speedup: None,
        });

        // frontier consumption: footprints + feasibility selection over
        // the in-flight windows of a 4-stage 1F1B pipeline
        let frontier = cost::search_span_mem(&ss, &db, 0, n, RecomputeSpec::Auto);
        let cap = frontier.iter().map(|p| p.peak_bytes(8, 2)).min().unwrap_or(u64::MAX);
        bench(
            &format!("remat/select_feasible/{layers}L"),
            Duration::from_millis(200),
            || {
                for stage_idx in 0..4usize {
                    let f = memory::inflight_microbatches(4, stage_idx, 8);
                    black_box(memory::select_feasible(&frontier, 8, f, cap));
                }
            },
        );
        // per-(segment, config) remat frontier construction alone
        bench(
            &format!("remat/frontier_points/{layers}L"),
            Duration::from_millis(200),
            || {
                for u in 0..ss.num_unique() {
                    let p = &db.segments[u];
                    for c in 0..p.configs.len() {
                        black_box(memory::remat_points(p, c, RecomputeSpec::Auto));
                    }
                }
            },
        );
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_search.json");
    match merge_bench_json(&path, &rows) {
        Ok(()) => println!("wrote {} rows to {}", rows.len(), path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
