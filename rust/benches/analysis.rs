//! Bench: AnalysisPasses (graph build → ParallelBlocks → segments) vs
//! model depth — the Fig. 13 left-hand scaling, as a perf target for §Perf.

use std::time::Duration;

use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::segment::extract_segments;
use cfp::util::bench::{bench, black_box};

fn main() {
    for preset in ["gpt-2.6b", "moe-7.1b", "llama-7b"] {
        for layers in [4usize, 16, 32] {
            let cfg = ModelCfg::preset(preset).with_layers(layers).scaled_for_eval();
            let g = build_training(&cfg);
            bench(
                &format!("analysis/{preset}/{layers}L ({} ops)", g.ops.len()),
                Duration::from_millis(800),
                || {
                    let bs = build_parallel_blocks(&g, 4);
                    let ss = extract_segments(&g, &bs);
                    black_box((bs.num_blocks(), ss.num_unique()));
                },
            );
        }
    }
    // graph construction separately
    for layers in [8usize, 32] {
        let cfg = ModelCfg::preset("gpt-2.6b").with_layers(layers).scaled_for_eval();
        bench(
            &format!("graph_build/gpt/{layers}L"),
            Duration::from_millis(500),
            || {
                black_box(build_training(&cfg).ops.len());
            },
        );
    }
}
