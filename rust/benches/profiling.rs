//! Bench: the full segment-profiling pipeline (Fig. 12's kernel) — one
//! unique-segment sweep incl. lowering, passes and simulation per config,
//! serial vs threaded (§4.3's parallel compilation).

use std::time::Duration;

use cfp::cluster::Platform;
use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::profiler::{profile_model, profile_model_cached, ProfileCache, ProfileOptions};
use cfp::segment::extract_segments;
use cfp::spmd::Mesh;
use cfp::util::bench::{bench, black_box};

fn main() {
    for preset in ["gpt-2.6b", "moe-7.1b"] {
        let cfg = ModelCfg::preset(preset).with_layers(4).scaled_for_eval();
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        for threads in [1usize, 4] {
            let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4))
                .with_threads(threads);
            let r = bench(
                &format!("profile_model/{preset}/threads={threads}"),
                Duration::from_secs(2),
                || {
                    black_box(profile_model(&g, &bs, &ss, &opts).profile_space());
                },
            );
            let db = profile_model(&g, &bs, &ss, &opts);
            println!(
                "  → {} programs in {} = {:.0} programs/s",
                db.profile_space(),
                cfp::util::bench::fmt_ns(r.median_ns),
                db.profile_space() as f64 / (r.median_ns * 1e-9)
            );
        }

        // warm persistent cache: the whole MetricsProfiling phase becomes
        // a fingerprint-keyed lookup
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let mut cache = ProfileCache::in_memory();
        profile_model_cached(&g, &bs, &ss, &opts, Some(&mut cache));
        bench(
            &format!("profile_model/{preset}/warm-cache"),
            Duration::from_secs(1),
            || {
                black_box(
                    profile_model_cached(&g, &bs, &ss, &opts, Some(&mut cache))
                        .profile_space(),
                );
            },
        );
    }
}
