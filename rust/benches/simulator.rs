//! Bench: SPMD lowering + cluster simulation throughput (instrs/s) — the
//! L3 hot path that every profiled configuration pays. §Perf target:
//! ≥ 10⁶ simulated instrs/s end-to-end.

use std::time::Duration;

use cfp::cluster::sim::ComputeModel;
use cfp::cluster::{simulate, Platform};
use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::spmd::{lower, passes, GlobalPlan, Mesh};
use cfp::util::bench::{bench, black_box};

fn main() {
    let cfg = ModelCfg::preset("gpt-2.6b").with_layers(8).scaled_for_eval();
    let g = build_training(&cfg);
    let bs = build_parallel_blocks(&g, 4);
    let plan = GlobalPlan::data_parallel(&bs, Mesh::flat(4));
    let platform = Platform::a100_pcie(4);
    let cm = ComputeModel::for_platform(&platform);

    let prog = lower(&g, &bs, &plan);
    let n_instr = prog.instrs.len();
    println!("program: {} instrs from {} ops", n_instr, g.ops.len());

    let r = bench(
        &format!("lower/gpt-8L ({} ops)", g.ops.len()),
        Duration::from_secs(1),
        || {
            black_box(lower(&g, &bs, &plan).instrs.len());
        },
    );
    println!(
        "  → {:.2}M ops lowered/s",
        g.ops.len() as f64 / (r.median_ns * 1e-9) / 1e6
    );

    let r = bench(
        &format!("simulate/gpt-8L ({n_instr} instrs)"),
        Duration::from_secs(1),
        || {
            black_box(simulate(&prog, &platform, 4, &cm).total_us);
        },
    );
    println!(
        "  → {:.2}M instrs simulated/s",
        n_instr as f64 / (r.median_ns * 1e-9) / 1e6
    );

    let mut prog2 = prog.clone();
    bench("passes/bucket+dispatch", Duration::from_millis(500), || {
        let mut p = prog2.clone();
        passes::bucket_gradients(&mut p, 64 << 20);
        passes::dispatch_alltoall_sendrecv(&mut p, 4);
        black_box(p.instrs.len());
    });
    prog2.instrs.clear();
}
