//! Figure 13: AnalysisPasses + ComposeSearch time vs number of hidden
//! layers (these phases grow with depth; profiling does not — §5.5).

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::harness::Table;
use cfp::models::ModelCfg;
use cfp::spmd::Mesh;

fn main() {
    let platform = Platform::a100_pcie(4).scaled_testbed();
    for preset in ["gpt-2.6b", "moe-7.1b", "llama-7b"] {
        println!("--- {preset} ---");
        let mut t = Table::new(&[
            "layers",
            "ops",
            "blocks",
            "AnalysisPasses (s)",
            "ComposeSearch (s)",
            "profile space",
        ]);
        for layers in [4usize, 8, 16, 32] {
            let model = ModelCfg::preset(preset)
                .with_layers(layers)
                .with_batch(8)
                .scaled_for_eval();
            let mut opts = CfpOptions::new(model, platform);
            opts.mesh = Mesh::flat(4);
            let r = run_cfp(&opts);
            t.row(vec![
                layers.to_string(),
                r.graph.ops.len().to_string(),
                r.blocks.num_blocks().to_string(),
                format!("{:.3}", r.timings.analysis_passes_s),
                format!("{:.3}", r.timings.compose_search_s),
                r.db.profile_space().to_string(),
            ]);
        }
        t.print();
        println!("(profile space must NOT grow with depth — §5.6)\n");
    }
}
