//! Figure 8: communication kernel overhead and achieved ("utilized") bus
//! bandwidth per framework, four models on 4×A100-PCIe.
//!
//! Shape target: PT-DDP low bandwidth (many small kernels), Megatron high
//! bandwidth but fixed-template volume, Alpa volume-optimal but inefficient
//! kernels, CFP the lowest overall comm overhead.

use cfp::cluster::Platform;
use cfp::coordinator::CfpOptions;
use cfp::harness::{eval_models, fmt_us, throughput_row, Table};
use cfp::spmd::Mesh;

fn main() {
    let platform = Platform::a100_pcie(4).scaled_testbed();
    let mesh = Mesh::flat(4);
    println!("Fig 8 — comm overhead + achieved bandwidth, 4x A100-PCIe\n");

    for model in eval_models() {
        let (_, c) = throughput_row(&model, platform, mesh);
        let mut opts = CfpOptions::new(model.clone(), platform);
        opts.mesh = mesh;
        let mut t = Table::new(&["framework", "comm time", "kernels", "achieved bw", "top kinds"]);
        for (name, plan) in [
            ("PT-DDP", &c.ddp),
            ("DS-Megatron", &c.megatron),
            ("Alpa", &c.alpa),
            ("CFP", &c.cfp),
        ] {
            let rep = c.result.simulate_choice(&opts, &plan.choice);
            let mut kinds: Vec<(&str, f64)> = rep
                .comm_by_kind
                .iter()
                .map(|(k, (_, _, t))| (*k, *t))
                .collect();
            kinds.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let top: Vec<String> = kinds
                .iter()
                .take(2)
                .map(|(k, t)| format!("{k} {}", fmt_us(*t)))
                .collect();
            t.row(vec![
                name.into(),
                fmt_us(rep.comm_us + rep.comm_inter_us),
                rep.comm_kernels.to_string(),
                format!("{:.1} GB/s", rep.achieved_bw_gbps),
                top.join(", "),
            ]);
        }
        println!("--- {} ---", model.name);
        t.print();
        println!();
    }
}
