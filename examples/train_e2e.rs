//! End-to-end driver (DESIGN.md deliverable): prove all layers compose.
//!
//! * L3 (rust): CFP searches the parallelization plan for the e2e model.
//! * L2+L1 (jax+pallas, AOT): the train-step executable with the Pallas
//!   attention/matmul kernels is loaded and run through PJRT.
//! * Trains a small GPT for a few hundred steps on a synthetic corpus and
//!   logs the loss curve (recorded in EXPERIMENTS.md §e2e).
//!
//! ```sh
//! make artifacts && cargo run --release --example train_e2e [-- --steps 300]
//! ```

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::harness::fmt_us;
use cfp::models::ModelCfg;
use cfp::runtime::Runtime;
use cfp::trainer::Trainer;
use cfp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300);
    let lr = args.get_f64("lr", 0.08) as f32;

    let rt = Runtime::open_default()?;
    let meta = rt
        .meta("train_step_gpt")
        .ok_or_else(|| anyhow::anyhow!("run `make artifacts` first"))?
        .clone();
    let hidden = meta.meta_usize("hidden").unwrap_or(256);
    let layers = meta.meta_usize("layers").unwrap_or(4);
    let n_params = meta.meta_usize("num_params").unwrap_or(0);

    // --- plan search (L3) on the same model shape -------------------------
    println!("== CFP plan for the e2e model (hidden {hidden}, {layers} layers) ==");
    let model = ModelCfg::preset("gpt-tiny"); // structure-matched small GPT
    let platform = Platform::a100_pcie(4);
    let mut opts = CfpOptions::new(model.with_layers(layers), platform);
    opts.compute = rt.calibrate_compute(&platform).ok();
    let r = run_cfp(&opts);
    println!(
        "   plan step estimate {} across {} GPUs; strategy of layer segment:",
        fmt_us(r.plan.time_us),
        opts.mesh.total()
    );
    if let Some(line) = r.describe_plan().first() {
        println!("   {line}");
    }

    // --- real training through PJRT (L2+L1) -------------------------------
    println!("\n== training train_step_gpt ({n_params} params) for {steps} steps ==");
    let mut tr = Trainer::new(&rt, "train_step_gpt", 42)?;
    let t0 = std::time::Instant::now();
    let curve = tr.train(steps, lr, (steps / 25).max(1))?;
    let wall = t0.elapsed().as_secs_f64();

    let first = *curve.first().unwrap();
    let last10: f64 =
        curve.iter().rev().take(10).sum::<f32>() as f64 / curve.len().min(10) as f64;
    println!("\nloss: {first:.4} → {last10:.4} (mean of last 10)");
    println!(
        "wall: {wall:.1}s for {steps} steps = {:.0} ms/step on the CPU PJRT client",
        1e3 * wall / steps as f64
    );
    assert!(
        last10 < first as f64 - 0.5,
        "training must reduce loss materially ({first} → {last10})"
    );
    println!("e2e OK — all three layers compose.");
    Ok(())
}
