//! Figure 9: computation + communication kernel time for the top-20
//! configurations ranked by Alpa's volume-based cost (ascending).
//!
//! Shape targets (§5.3): measured comm time broadly increases with the
//! symbolic rank but is non-monotonic — configs with near-equal theoretical
//! cost differ up to ~2× in measured time, and the fastest config is often
//! NOT rank 0 (in the paper's MoE, rank 14 won with 1.45× the minimal
//! theoretical cost).

use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::cluster::Platform;
use cfp::harness::{eval_models, fmt_us, Table};
use cfp::spmd::Mesh;
use cfp::util::stats;

fn main() {
    let platform = Platform::a100_pcie(4).scaled_testbed();
    for model in eval_models() {
        let mut opts = CfpOptions::new(model.clone(), platform);
        opts.mesh = Mesh::flat(4);
        let r = run_cfp(&opts);

        // the repeated layer segment drives the ranking (uniform configs)
        let u = r
            .segments
            .unique
            .iter()
            .max_by_key(|u| u.count)
            .unwrap()
            .id;
        let prof = &r.db.segments[u];
        let mut order: Vec<usize> = (0..prof.configs.len()).collect();
        order.sort_by_key(|&c| prof.symbolic_volume[c]);
        order.truncate(20);

        println!("--- {} (layer segment, top-20 by Alpa volume cost) ---", model.name);
        let mut t = Table::new(&["rank", "sym vol (MB)", "comm", "compute", "total"]);
        let mut sym: Vec<f64> = Vec::new();
        let mut meas: Vec<f64> = Vec::new();
        for (rank, &c) in order.iter().enumerate() {
            let total = prof.t_c_us[c] + prof.t_p_us[c];
            t.row(vec![
                rank.to_string(),
                format!("{:.1}", prof.symbolic_volume[c] as f64 / 1e6),
                fmt_us(prof.t_c_us[c]),
                fmt_us(prof.t_p_us[c]),
                fmt_us(total),
            ]);
            sym.push(prof.symbolic_volume[c] as f64);
            meas.push(prof.t_c_us[c]);
        }
        t.print();

        let best_rank = meas
            .iter()
            .enumerate()
            .min_by(|a, b| {
                (a.1 + prof.t_p_us[order[a.0]])
                    .partial_cmp(&(b.1 + prof.t_p_us[order[b.0]]))
                    .unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        let corr = stats::pearson(&sym, &meas);
        println!(
            "pearson(sym volume, measured comm) = {corr:.2}; fastest config at \
             symbolic rank {best_rank}\n"
        );
    }
}
