//! Figure 11: training throughput under a per-device memory cap, LLAMA
//! with growing depth (left) and growing batch (right).
//!
//! Shape targets (§5.4): Alpa ignores memory in its search → OOMs first as
//! depth/batch grow; ZeRO-1 never OOMs but pays communication (lowest
//! throughput); CFP rides the cap by mixing memory-hungry and
//! memory-lean configs per segment, training deeper/larger than Alpa at
//! higher throughput than ZeRO-1.

use cfp::baselines;
use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::harness::{fmt_bytes, Table};
use cfp::models::ModelCfg;
use cfp::spmd::Mesh;

fn main() {
    let base = ModelCfg::preset("llama-7b").with_batch(16).scaled_for_eval();
    let platform = Platform::a100_pcie(4).scaled_testbed();

    // calibrate the cap so OOM bites mid-sweep (our tensors are scaled-down;
    // the paper's 40 GB plays the same role at full scale)
    let probe = {
        let mut opts = CfpOptions::new(base.clone().with_layers(8), platform);
        opts.mesh = Mesh::flat(4);
        opts.mem_cap = None;
        run_cfp(&opts)
    };
    let cap = (probe.plan.mem_bytes as f64 * 1.6) as u64;
    println!(
        "Fig 11 — LLAMA under memory cap {} per device (4x A100-PCIe)\n",
        fmt_bytes(cap)
    );

    println!("-- left: fixed batch {}, growing depth --", base.batch);
    let mut t = Table::new(&["layers", "CFP", "Alpa", "ZeRO-1"]);
    for layers in [4usize, 6, 8, 10, 12, 16] {
        t.row(run_row(&base.clone().with_layers(layers), platform, cap, layers.to_string()));
    }
    t.print();

    println!("\n-- right: fixed depth 6, growing batch --");
    let mut t = Table::new(&["batch", "CFP", "Alpa", "ZeRO-1"]);
    for batch in [8usize, 16, 32, 64] {
        t.row(run_row(
            &base.clone().with_layers(6).with_batch(batch),
            platform,
            cap,
            batch.to_string(),
        ));
    }
    t.print();
    println!("\n(cells: steps/s; OOM = plan exceeds the cap)");
}

fn run_row(model: &ModelCfg, platform: Platform, cap: u64, label: String) -> Vec<String> {
    let mut opts = CfpOptions::new(model.clone(), platform);
    opts.mesh = Mesh::flat(4);
    opts.mem_cap = Some(cap);
    let r = run_cfp(&opts);

    let steps_per_s = |us: f64| format!("{:.2}", 1e6 / us);

    // CFP honours the cap in-search
    let cfp = if r.plan.mem_bytes <= cap {
        steps_per_s(r.plan.time_us)
    } else {
        "OOM".into()
    };
    // Alpa searches without the cap (§5.4)
    let alpa = baselines::alpa_plan(&r.segments, &r.db);
    let alpa_cell = if alpa.mem_bytes <= cap {
        steps_per_s(alpa.time_us)
    } else {
        "OOM".into()
    };
    // ZeRO-1: DP + optimizer sharding
    let z = baselines::zero1_plan(&r.graph, &r.blocks, &r.segments, &r.db, 4, 2.0);
    let z_cell = if z.mem_bytes <= cap {
        steps_per_s(z.time_us)
    } else {
        "OOM".into()
    };
    vec![label, cfp, alpa_cell, z_cell]
}
