//! Figure 1: communication volume vs communication kernel overhead of 4
//! intra-operator parallelism configurations, 2 LLAMA layers, 4 GPUs.
//!
//! Paper's point: minimizing volume does NOT minimize communication time
//! or step time — the volume-optimal config is not the fastest.

use cfp::cluster::sim::ComputeModel;
use cfp::cluster::{simulate, Platform};
use cfp::harness::{fmt_bytes, fmt_us, Table};
use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::spmd::{lower, passes, GlobalPlan, Mesh};

fn main() {
    // shape chosen so the volume ranking and the time ranking disagree
    // (params >> activations: TP volume < DP volume, as in the paper's
    // batch-64 LLAMA-7B layers)
    let mut model = ModelCfg::preset("llama-7b").with_layers(2).with_batch(8);
    model.hidden = 512;
    model.ffn = 1408;
    model.heads = 8;
    model.seq = 64;
    model.vocab = 1024;
    let g = build_training(&model);
    let bs = build_parallel_blocks(&g, 4);
    let platform = Platform::a100_pcie(4).scaled_testbed();
    let cm = ComputeModel::for_platform(&platform);

    println!("Fig 1 — 2 LLAMA layers, 4x A100-PCIe, batch {}", model.batch);
    let mut t = Table::new(&[
        "config",
        "comm volume",
        "comm kernels",
        "comm time",
        "step time",
    ]);

    let configs: Vec<(&str, GlobalPlan)> = vec![
        ("DP (batch split)", GlobalPlan::uniform(&bs, "m", Mesh::flat(4)).unwrap()),
        ("TP column (N split)", GlobalPlan::uniform(&bs, "n", Mesh::flat(4)).unwrap()),
        ("TP row (K split)", GlobalPlan::uniform(&bs, "k", Mesh::flat(4)).unwrap()),
        ("Megatron (col+row)", megatron_plan(&g, &bs)),
    ];

    let mut rows: Vec<(String, u64, f64, f64)> = Vec::new();
    for (name, plan) in configs {
        let mut prog = lower(&g, &bs, &plan);
        passes::bucket_gradients(&mut prog, 64 << 20);
        passes::dispatch_alltoall_sendrecv(&mut prog, 4);
        let rep = simulate(&prog, &platform, 4, &cm);
        t.row(vec![
            name.to_string(),
            fmt_bytes(rep.comm_volume),
            rep.comm_kernels.to_string(),
            fmt_us(rep.comm_us),
            fmt_us(rep.total_us),
        ]);
        rows.push((name.to_string(), rep.comm_volume, rep.comm_us, rep.total_us));
    }
    t.print();

    let min_vol = rows.iter().min_by_key(|r| r.1).unwrap();
    let min_time = rows
        .iter()
        .min_by(|a, b| a.3.partial_cmp(&b.3).unwrap())
        .unwrap();
    println!(
        "\nvolume-optimal: {:<20} fastest: {:<20} {}",
        min_vol.0,
        min_time.0,
        if min_vol.0 == min_time.0 {
            "(same — unusual for this shape)"
        } else {
            "← minimizing volume picked the wrong config (the paper's Fig. 1 point)"
        }
    );
}

fn megatron_plan(g: &cfp::graph::Graph, bs: &cfp::pblock::BlockSet) -> GlobalPlan {
    let choice = bs
        .blocks
        .iter()
        .map(|b| {
            let name = &g.ops[b.entry].name;
            let want = if name.contains("qkv") || name.contains("gate") || name.contains("up")
            {
                "n"
            } else if name.contains("out_proj") || name.contains("down") {
                "k"
            } else {
                "m"
            };
            b.strategies.iter().position(|s| s.label == want).unwrap_or(0)
        })
        .collect();
    GlobalPlan { choice, mesh: Mesh::flat(4) }
}
