//! Figure 7: average training throughput of PyTorch-DDP, DeepSpeed-Megatron,
//! Alpa and CFP across {BERT, GPT, MoE, LLAMA} × {4×A100-PCIe, 8×A100-PCIe,
//! 2×8 A100, 4×V100-NVLink}, plus the §5.2 headline speedups.

use cfp::harness::{eval_models, eval_platforms, fmt_us, throughput_row, Table};

fn main() {
    let mut speedups: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
    for (platform, mesh) in eval_platforms() {
        println!(
            "\n=== {} ({} GPUs{}) ===",
            platform.name,
            mesh.intra * mesh.nodes,
            if mesh.nodes > 1 { ", 2 nodes" } else { "" }
        );
        let mut t = Table::new(&["model", "PT-DDP", "DS-Megatron", "Alpa", "CFP", "CFP/Alpa"]);
        for model in eval_models() {
            let (row, _) = throughput_row(&model, platform, mesh);
            t.row(vec![
                row.model.clone(),
                fmt_us(row.pt_us),
                fmt_us(row.dsm_us),
                fmt_us(row.alpa_us),
                fmt_us(row.cfp_us),
                format!("{:.2}x", row.cfp_over_alpa),
            ]);
            speedups.entry(row.model).or_default().push(row.cfp_over_alpa);
        }
        t.print();
    }

    println!("\n=== §5.2 headline: CFP speedup over Alpa (per model) ===");
    let mut t = Table::new(&["model", "avg", "max", "paper max"]);
    let paper_max = |m: &str| match m {
        m if m.contains("gpt") => "1.51x",
        m if m.contains("llama") => "1.31x",
        m if m.contains("moe") => "3.43x",
        _ => "2.01x", // bert, multi-node
    };
    for (model, xs) in &speedups {
        let avg = xs.iter().sum::<f64>() / xs.len() as f64;
        let max = xs.iter().cloned().fold(0.0, f64::max);
        t.row(vec![
            model.clone(),
            format!("{avg:.2}x"),
            format!("{max:.2}x"),
            paper_max(model).into(),
        ]);
    }
    t.print();
    println!("(shape target: CFP ≥ 1x everywhere, biggest gaps on MoE@PCIe and multi-node)");
}
