//! Figure 10: CFP's composed (Eq. 8) cost prediction vs the "actual" step
//! time, GPT across parallel configurations, on both platforms. The paper
//! reports RMSE 0.033 (A100-PCIe) and 0.0079 (V100-NVLink) on normalized
//! times — the NVLink platform predicts better because cross-segment
//! communication is a smaller share.
//!
//! Our "actual" is a whole-graph lowering+simulation (vs the per-segment
//! composition used for prediction); the composition error it measures is
//! exactly the paper's boundary-effects error.

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::harness::Table;
use cfp::models::ModelCfg;
use cfp::spmd::Mesh;
use cfp::util::stats;

fn main() {
    let model = ModelCfg::preset("gpt-6.7b")
        .with_layers(4)
        .with_batch(16)
        .scaled_for_eval();
    for (platform, mesh) in [
        (Platform::a100_pcie(4).scaled_testbed(), Mesh::flat(4)),
        (Platform::v100_nvlink().scaled_testbed(), Mesh::flat(4)),
    ] {
        let mut opts = CfpOptions::new(model.clone(), platform);
        opts.mesh = mesh;
        let r = run_cfp(&opts);

        // sample uniform configurations of the layer segment (paper limits
        // to fingerprint-uniform configs for this figure)
        let u = r.segments.unique.iter().max_by_key(|u| u.count).unwrap().id;
        let n_cfg = r.db.segments[u].configs.len();
        let step = (n_cfg / 12).max(1);
        let mut pred = Vec::new();
        let mut actual = Vec::new();
        let mut t = Table::new(&["config", "predicted (ms)", "actual (ms)", "err %"]);
        for c in (0..n_cfg).step_by(step) {
            let choice: Vec<usize> = r
                .segments
                .instances
                .iter()
                .map(|i| if i.unique_id == u { c } else { 0 })
                .collect();
            let (p_us, _) = cfp::cost::plan_cost(&r.segments, &r.db, &choice);
            let a_us = r.simulate_choice(&opts, &choice).total_us;
            t.row(vec![
                format!("{c}"),
                format!("{:.3}", p_us / 1e3),
                format!("{:.3}", a_us / 1e3),
                format!("{:+.1}%", 100.0 * (p_us - a_us) / a_us),
            ]);
            pred.push(p_us);
            actual.push(a_us);
        }
        // normalized RMSE (paper normalizes to step time)
        let scale = stats::mean(&actual);
        let pn: Vec<f64> = pred.iter().map(|p| p / scale).collect();
        let an: Vec<f64> = actual.iter().map(|a| a / scale).collect();
        let rmse = stats::rmse(&pn, &an);
        println!("--- {} ---", platform.name);
        t.print();
        println!(
            "normalized RMSE = {rmse:.4}  (paper: 0.0329 PCIe / 0.0079 NVLink)\n"
        );
    }
}
