//! Two-level planner evaluation: single-stage CFP vs inter-op pipeline
//! staging vs the naive equal-split pipeline, across the GPT/LLAMA/MoE
//! presets on the single-node and two-node testbeds.
//!
//! Usage: `cargo run --release --example pipeline_eval [-- --microbatches M]`

use cfp::cluster::Platform;
use cfp::harness::{fmt_bytes, fmt_us, pipeline_eval_models, pipeline_row, Table};
use cfp::spmd::Mesh;
use cfp::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let microbatches = args.get_usize("microbatches", 8);
    let platforms = [
        (Platform::a100_pcie(4).scaled_testbed(), Mesh::flat(4)),
        (Platform::a100_two_node().scaled_testbed(), Mesh { intra: 8, nodes: 2 }),
    ];
    for (platform, mesh) in platforms {
        println!(
            "\n=== {} ({} GPUs, m={microbatches}) ===",
            platform.name,
            mesh.total()
        );
        let mut t = Table::new(&[
            "model",
            "topology",
            "single-stage",
            "two-level",
            "naive pipeline",
            "stages",
            "bubble",
            "peak mem/dev",
            "vs single",
            "vs naive",
            "prof hit",
            "prof miss",
            "search",
        ]);
        for model in pipeline_eval_models() {
            let (row, _) = pipeline_row(&model, platform, mesh, microbatches);
            let naive_feasible = row.naive_us.is_finite();
            t.row(vec![
                row.model.clone(),
                row.topology.clone(),
                fmt_us(row.single_us),
                fmt_us(row.two_level_us),
                if naive_feasible { fmt_us(row.naive_us) } else { "no valid split".into() },
                row.stages.to_string(),
                format!("{:.1}%", row.bubble * 100.0),
                fmt_bytes(row.peak_mem_bytes),
                format!("{:.2}x", row.single_us / row.two_level_us),
                if naive_feasible {
                    format!("{:.2}x", row.naive_us / row.two_level_us)
                } else {
                    "-".into()
                },
                row.profile_hits.to_string(),
                row.profile_misses.to_string(),
                fmt_us(row.search_us),
            ]);
        }
        t.print();
    }
    println!(
        "\n(shape target: two-level ≤ single-stage everywhere — k = 1 is in the \
         search space — and strictly below the naive pipeline wherever staging \
         or intra-op co-optimization matters)"
    );
}
