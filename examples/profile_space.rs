//! §5.5 profile-space accounting: ParallelBlocks per layer, strategies per
//! block, configs per unique segment, resharding groups — the counts the
//! paper quotes (4 blocks/layer, 3 strategies each, 81 configs/segment,
//! 2·81 + 2·9 = 180 programs for GPT; extra expert dim for MoE).

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::harness::Table;
use cfp::models::ModelCfg;
use cfp::spmd::Mesh;

fn main() {
    let platform = Platform::a100_pcie(4).scaled_testbed();
    for preset in ["bert-large", "gpt-2.6b", "llama-7b", "moe-7.1b"] {
        let model = ModelCfg::preset(preset).with_layers(4).scaled_for_eval();
        let mut opts = CfpOptions::new(model, platform);
        opts.mesh = Mesh::flat(4);
        let r = run_cfp(&opts);
        println!("--- {preset} (4 layers) ---");
        let mut t = Table::new(&["segment", "instances", "blocks", "strategies/block", "configs"]);
        for u in &r.segments.unique {
            let inst = &r.segments.instances[u.rep];
            let strat: Vec<String> = inst
                .blocks
                .iter()
                .map(|&b| r.blocks.blocks[b].strategies.len().to_string())
                .collect();
            t.row(vec![
                format!("u{}", u.id),
                u.count.to_string(),
                inst.blocks.len().to_string(),
                strat.join("x"),
                r.db.segments[u.id].configs.len().to_string(),
            ]);
        }
        t.print();
        let rs: usize = r.db.reshard.values().map(|t| t.programs).sum();
        println!(
            "programs: {} segment configs + {} reshard groups = {} total \
             (paper GPT: 2*81 + 2*9 = 180)\n",
            r.db.profile_space() - rs,
            rs,
            r.db.profile_space()
        );
    }
}
