//! Figure 14 / §5.7 case studies:
//!  (a,b) GShard-MoE on A100-PCIe — Alpa picks expert parallelism whose
//!        All-to-All degenerates to SendRecv kernels; CFP picks TP over the
//!        expert FFN, whose aggregation the compiler rewrites
//!        AllReduce→ReduceScatter. Batch-size dependent (§5.7: switch near
//!        batch 96 at full scale).
//!  (c,d) LLAMA on V100-NVLink — Alpa splits parameters, dragging in the
//!        RNG-sync AllReduce; CFP goes full-DP with fused gradient sync.

use cfp::baselines;
use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::harness::{fmt_us, Table};
use cfp::models::ModelCfg;
use cfp::spmd::Mesh;

fn main() {
    moe_case();
    llama_case();
}

fn describe(r: &cfp::coordinator::CfpResult, choice: &[usize], seg: usize) -> String {
    let inst = &r.segments.instances[seg];
    let cfg = &r.db.segments[inst.unique_id].configs[choice[seg]];
    inst.blocks
        .iter()
        .zip(&cfg.strategy)
        .map(|(&b, &s)| {
            let blk = &r.blocks.blocks[b];
            let entry = &r.graph.ops[blk.entry].name;
            let short = entry.rsplit('/').next().unwrap_or(entry);
            format!("{short}={}", blk.strategies[s].label)
        })
        .collect::<Vec<_>>()
        .join(" ")
}

fn comm_kinds(rep: &cfp::cluster::SimReport) -> String {
    let mut kinds: Vec<(&str, f64)> =
        rep.comm_by_kind.iter().map(|(k, (_, _, t))| (*k, *t)).collect();
    kinds.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    kinds
        .iter()
        .take(3)
        .map(|(k, t)| format!("{k}={}", fmt_us(*t)))
        .collect::<Vec<_>>()
        .join(" ")
}

fn moe_case() {
    println!("=== (a,b) GShard-MoE on 4x A100-PCIe ===");
    let platform = Platform::a100_pcie(4).scaled_testbed();
    let mut t =
        Table::new(&["batch", "framework", "moe-segment strategies", "comm", "top comm kinds"]);
    for batch in [8usize, 32] {
        let model = ModelCfg::preset("moe-7.1b")
            .with_layers(4)
            .with_batch(batch)
            .scaled_for_eval();
        let mut opts = CfpOptions::new(model, platform);
        opts.mesh = Mesh::flat(4);
        let r = run_cfp(&opts);
        let alpa = baselines::alpa_plan(&r.segments, &r.db);
        // the moe segment = the one containing an expert block
        let seg = r
            .segments
            .instances
            .iter()
            .position(|i| {
                i.blocks.iter().any(|&b| {
                    r.graph.ops[r.blocks.blocks[b].entry].name.contains("expert")
                })
            })
            .unwrap_or(0);
        for (name, choice) in [("Alpa", &alpa.choice), ("CFP", &r.plan.choice)] {
            let rep = r.simulate_choice(&opts, choice);
            t.row(vec![
                batch.to_string(),
                name.into(),
                describe(&r, choice, seg),
                fmt_us(rep.comm_us),
                comm_kinds(&rep),
            ]);
        }
    }
    t.print();
    println!(
        "(paper: Alpa's expert-parallel plan pays SendRecv-dispatched \
         All-to-All; CFP's TP plan benefits from the ReduceScatter rewrite)\n"
    );
}

fn llama_case() {
    println!("=== (c,d) LLAMA on 4x V100-NVLink ===");
    let platform = Platform::v100_nvlink().scaled_testbed();
    let model = ModelCfg::preset("llama-7b")
        .with_layers(4)
        .with_batch(32)
        .scaled_for_eval();
    let mut opts = CfpOptions::new(model, platform);
    opts.mesh = Mesh::flat(4);
    let r = run_cfp(&opts);
    let alpa = baselines::alpa_plan(&r.segments, &r.db);

    let mut t = Table::new(&[
        "framework",
        "layer-segment strategies",
        "comm",
        "compute",
        "top comm kinds",
    ]);
    for (name, choice) in [("Alpa", &alpa.choice), ("CFP", &r.plan.choice)] {
        let rep = r.simulate_choice(&opts, choice);
        t.row(vec![
            name.into(),
            describe(&r, choice, 0),
            fmt_us(rep.comm_us),
            fmt_us(rep.compute_us),
            comm_kinds(&rep),
        ]);
    }
    t.print();
    println!(
        "(paper: Alpa's parameter-split plan triggers RNG-sync AllReduces \
         and extra data movement; CFP's batch-split plan merges gradient \
         sync into few fused kernels)"
    );
}
