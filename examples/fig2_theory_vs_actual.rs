//! Figure 2: theoretical communication volume vs actual lowered
//! communication for DP and TP on a transformer layer (§2.2's worked
//! example).
//!
//! The paper computes: DP volume = 4·4·h² (parameter AllReduce),
//! TP volume = 4·b·s·h (activation AllReduce) — TP "wins" on volume, yet
//! after downstream compilation, DP's bucketed AllReduce beats TP, whose
//! replicated dropout masks drag in RNG-sync AllReduces. On 4×A100-PCIe
//! the paper measured DP comm time ≈ 0.6× TP's.

use cfp::cluster::sim::ComputeModel;
use cfp::cluster::{simulate, Platform};
use cfp::harness::{fmt_bytes, fmt_us, Table};
use cfp::models::{build_training, ModelCfg};
use cfp::pblock::build_parallel_blocks;
use cfp::spmd::{lower, passes, GlobalPlan, Mesh};

fn main() {
    let mut model = ModelCfg::preset("gpt-2.6b").with_layers(2).with_batch(8);
    model.hidden = 512;
    model.ffn = 2048;
    model.heads = 8;
    model.seq = 64;
    model.vocab = 1024;
    let (h, b, s) = (model.hidden as u64, model.batch as u64, model.seq as u64);
    let g = build_training(&model);
    let bs = build_parallel_blocks(&g, 4);
    let platform = Platform::a100_pcie(4).scaled_testbed();
    let cm = ComputeModel::for_platform(&platform);

    // §2.2 theoretical volumes (per layer, f32): DP = params·4B;
    // TP = activation AllReduces (attn + mlp outputs per layer)
    let params_per_layer = 4 * h * h + 2 * h * model.ffn as u64;
    let theory_dp = 2 * params_per_layer * 4;
    let theory_tp = 2 * 2 * b * s * h * 4;

    println!(
        "Fig 2 — transformer×2, hidden {}, batch {}, 4x A100-PCIe",
        model.hidden, model.batch
    );
    println!(
        "theoretical volume: DP {}   TP {}   (TP 'wins' on paper)",
        fmt_bytes(theory_dp),
        fmt_bytes(theory_tp)
    );

    let mut t = Table::new(&[
        "config",
        "theory vol",
        "actual vol",
        "comm kernels",
        "comm time",
    ]);
    let mut times = Vec::new();
    for (name, label, theory) in
        [("DP", "m", theory_dp), ("TP (Megatron)", "megatron", theory_tp)]
    {
        let plan = if label == "megatron" {
            megatron_plan(&g, &bs)
        } else {
            GlobalPlan::uniform(&bs, label, Mesh::flat(4)).unwrap()
        };
        let mut prog = lower(&g, &bs, &plan);
        passes::bucket_gradients(&mut prog, 64 << 20);
        let rep = simulate(&prog, &platform, 4, &cm);
        t.row(vec![
            name.into(),
            fmt_bytes(theory),
            fmt_bytes(rep.comm_volume),
            rep.comm_kernels.to_string(),
            fmt_us(rep.comm_us),
        ]);
        times.push(rep.comm_us);
    }
    t.print();

    let ratio = times[0] / times[1];
    println!(
        "\nDP comm time / TP comm time = {ratio:.2} (paper: ≈0.60 — DP wins \
         despite larger theoretical volume)"
    );
    println!(
        "causes implemented: gradient bucketing (DP), RNG replication \
         AllReduce + per-block activation AllReduces (TP)"
    );
    assert!(ratio < 1.0, "DP must beat TP on comm time for this shape");
}

fn megatron_plan(g: &cfp::graph::Graph, bs: &cfp::pblock::BlockSet) -> GlobalPlan {
    let choice = bs
        .blocks
        .iter()
        .map(|b| {
            let name = &g.ops[b.entry].name;
            let want = if name.contains("qkv") || name.contains("fc1") {
                "n"
            } else if name.contains("out_proj") || name.contains("fc2") {
                "k"
            } else {
                "m"
            };
            b.strategies.iter().position(|s| s.label == want).unwrap_or(0)
        })
        .collect();
    GlobalPlan { choice, mesh: Mesh::flat(4) }
}
