//! Cold vs. warm profile cache: run the CFP pipeline twice against the
//! same on-disk cache file and show MetricsProfiling collapsing to a
//! lookup on the second run (the cross-run extension of the paper's
//! fingerprint amortization, §4.2/§5.5).
//!
//! ```sh
//! cargo run --release --example cache_warm [-- --layers 16 --threads 4]
//! ```

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions, CfpResult};
use cfp::models::ModelCfg;
use cfp::util::cli::Args;

fn report(tag: &str, r: &CfpResult) {
    println!(
        "{tag:>5}: plan step {:>10.1}µs | profiled {:>3} segment(s), {} cache hit(s) | \
         MetricsProfiling {:.4}s, total profiling {:.4}s",
        r.plan.time_us,
        r.db.stats.cache_misses,
        r.db.stats.cache_hits,
        r.timings.metrics_profiling_s,
        r.timings.exec_compiling_s + r.timings.metrics_profiling_s,
    );
}

fn main() {
    let args = Args::from_env();
    let layers = args.get_usize("layers", 8);
    let path = args
        .get_path("cache")
        .unwrap_or_else(|| std::env::temp_dir().join("cfp-cache-warm-demo.json"));
    std::fs::remove_file(&path).ok(); // always demo a genuine cold start

    let mut opts = CfpOptions::new(
        ModelCfg::preset("gpt-2.6b").with_layers(layers).with_batch(8).scaled_for_eval(),
        Platform::a100_pcie(4),
    )
    .with_cache(&path);
    opts.threads = args.get_usize("threads", 1);

    println!(
        "model gpt-2.6b ({layers} layers, scaled) on a100-pcie-4; cache file {}",
        path.display()
    );
    let cold = run_cfp(&opts);
    report("cold", &cold);
    let warm = run_cfp(&opts);
    report("warm", &warm);

    assert_eq!(cold.plan.choice, warm.plan.choice, "warm plan must be identical");
    assert_eq!(warm.db.stats.cache_misses, 0, "warm run must not profile");
    println!(
        "warm MetricsProfiling is {}; plans are bit-identical",
        if warm.timings.metrics_profiling_s == 0.0 { "zero" } else { "nonzero (?)" }
    );
    std::fs::remove_file(&path).ok();
}
