//! Quickstart: the three layers in one page.
//!
//! 1. Load an AOT-compiled HLO artifact (L1 Pallas kernel + L2 jax graph,
//!    lowered once by `make artifacts`) and execute it from rust via PJRT.
//! 2. Run the CFP analysis (L3) on a small GPT and print the chosen
//!    intra-operator parallelism plan.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::harness::{fmt_bytes, fmt_us};
use cfp::models::ModelCfg;
use cfp::runtime::Runtime;
use cfp::util::Pcg64;

fn main() -> anyhow::Result<()> {
    // --- 1. the AOT → PJRT path -----------------------------------------
    println!("== PJRT: run the quickstart artifact (one GPT block fwd) ==");
    match Runtime::open_default() {
        Ok(rt) => {
            let mut rng = Pcg64::new(7);
            let inputs = rt.random_inputs("quickstart", &mut rng)?;
            let t0 = std::time::Instant::now();
            let out = rt.run("quickstart", &inputs)?;
            let v = out[0].to_vec::<f32>()?;
            println!(
                "   output tensor: {} elements, first = {:.5}, ran in {:.2?}",
                v.len(),
                v[0],
                t0.elapsed()
            );
        }
        Err(e) => println!("   (skipped — no artifacts: {e}; run `make artifacts`)"),
    }

    // --- 2. the CFP search ------------------------------------------------
    println!("\n== CFP: search an intra-op plan for gpt-tiny on 4x A100-PCIe ==");
    let opts = CfpOptions::new(
        ModelCfg::preset("gpt-tiny").with_layers(4),
        Platform::a100_pcie(4),
    );
    let r = run_cfp(&opts);
    println!(
        "   {} ops → {} ParallelBlocks → {} segments ({} unique), {} profiled programs",
        r.graph.ops.len(),
        r.blocks.num_blocks(),
        r.segments.instances.len(),
        r.segments.num_unique(),
        r.db.profile_space(),
    );
    println!(
        "   plan: step {} / device-mem {}",
        fmt_us(r.plan.time_us),
        fmt_bytes(r.plan.mem_bytes)
    );
    for line in r.describe_plan().iter().take(3) {
        println!("   {line}");
    }
    println!("   ... (see `cfp search` for the full plan)");
    Ok(())
}
