//! Figure 12: compiling + profiling time for unique segments vs batch size
//! (GPT-2.6B, MoE-7.1B, LLAMA-7B on a 24-core + 4×A100 host in the paper).
//!
//! Shape targets (§5.5): ExecCompiling ≈ flat in batch size;
//! MetricsProfiling grows with batch (bigger steps to time);
//! OptimizedOverall (parallel compile + overlap + dynamic limit) well below
//! the naive sum.

use cfp::cluster::Platform;
use cfp::coordinator::{run_cfp, CfpOptions};
use cfp::harness::Table;
use cfp::models::ModelCfg;
use cfp::spmd::Mesh;

fn main() {
    let platform = Platform::a100_pcie(4).scaled_testbed();
    for preset in ["gpt-2.6b", "moe-7.1b", "llama-7b"] {
        println!("--- {preset} (estimated real-testbed seconds) ---");
        let mut t = Table::new(&[
            "batch",
            "ExecCompiling",
            "MetricsProfiling",
            "naive total",
            "OptimizedOverall",
            "our wall (s)",
        ]);
        for batch in [2usize, 8, 32] {
            let model = ModelCfg::preset(preset)
                .with_layers(4)
                .with_batch(batch)
                .scaled_for_eval();
            let mut opts = CfpOptions::new(model, platform);
            opts.mesh = Mesh::flat(4);
            opts.threads = 8; // paper host: 24-core; compile parallelism
            let r = run_cfp(&opts);
            let s = &r.db.stats;
            t.row(vec![
                batch.to_string(),
                format!("{:.1}", s.est_compile_s),
                format!("{:.1}", s.est_profile_s),
                format!("{:.1}", s.est_compile_s + s.est_profile_s),
                format!("{:.1}", s.est_optimized_s),
                format!("{:.2}", s.wall_s),
            ]);
        }
        t.print();
        println!();
    }
    println!("(paper claim: search completes in < 15 minutes — check OptimizedOverall)");
}
