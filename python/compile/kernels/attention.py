"""L1 Pallas kernel: fused multi-head attention with streaming softmax.

TPU-shaped design (see DESIGN.md §5 Hardware-Adaptation): the GPU paperland
"flash" pattern (threadblock tiles in shared memory) becomes a BlockSpec
HBM→VMEM schedule here. Each grid step owns one (batch·head, q-block) tile
resident in VMEM and streams K/V blocks through a fori_loop, maintaining the
online max/sum rescaling so the softmax never materializes the (S, S) score
matrix. The two BMMs target the MXU with D-minor layouts.

Always lowered with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (vs ``ref.attention_ref``) is what the
AOT artifacts need. Real-TPU VMEM/MXU estimates live in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, causal, scale, q_offset_blocks):
    """One (B·H, q-block) tile: stream K/V in ``block_k`` chunks.

    q_ref: (1, block_q, D); k_ref/v_ref: (1, S, D); o_ref: (1, block_q, D).
    """
    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    block_q, d = q.shape
    s_total = k_ref.shape[1]
    num_kb = s_total // block_k
    qi = pl.program_id(1)
    row = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)

    def body(kb, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], kb * block_k, block_k, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], kb * block_k, block_k, 0)
        s = q @ k.astype(jnp.float32).T               # (bq, bk) — MXU BMM #1
        if causal:
            col = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1
            )
            s = jnp.where(row >= col, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        # Rows that are fully masked keep m == -inf; exp(-inf - -inf) would
        # be NaN, so pin the rescale factor to 0 there.
        alpha = jnp.where(m == NEG_INF, 0.0, jnp.exp(m - m_new))
        p = jnp.exp(s - m_new)
        p = jnp.where(jnp.isneginf(s), 0.0, p)
        l_new = alpha * l + p.sum(axis=-1, keepdims=True)
        acc_new = alpha * acc + p @ v.astype(jnp.float32)  # MXU BMM #2
        return m_new, l_new, acc_new

    if causal:
        # Skip K blocks strictly above the diagonal of this q tile.
        last = (qi + q_offset_blocks + 1) * (block_q // block_k)
        num_iters = jnp.minimum(num_kb, last)
    else:
        num_iters = num_kb
    m, l, acc = jax.lax.fori_loop(0, num_iters, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k")
)
def attention(q, k, v, *, causal=False, scale=None, block_q=None, block_k=None):
    """Fused attention. q, k, v: (B, H, S, D) → (B, H, S, D).

    ``block_q``/``block_k`` default to the largest divisor of S ≤ 128 so the
    VMEM tile stays MXU-friendly; both must divide S.
    """
    b, h, s, d = q.shape
    if scale is None:
        scale = 1.0 / (d**0.5)
    if block_q is None:
        block_q = _largest_divisor(s, 128)
    if block_k is None:
        block_k = _largest_divisor(s, 128)
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must be divisible by block_q={block_q}, block_k={block_k}")
    if causal and block_q % block_k:
        raise ValueError("causal attention requires block_k | block_q")

    bh = b * h
    qr = q.reshape(bh, s, d)
    kr = k.reshape(bh, s, d)
    vr = v.reshape(bh, s, d)

    grid = (bh, s // block_q)
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel,
            block_k=block_k,
            causal=causal,
            scale=scale,
            q_offset_blocks=0,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=True,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)


def _largest_divisor(n, cap):
    for c in range(min(n, cap), 0, -1):
        if n % c == 0:
            return c
    return 1


def vmem_bytes(block_q, block_k, s, d, itemsize=4):
    """Static VMEM footprint estimate for one grid step (TPU planning).

    q tile + streamed k/v block pair (double-buffered) + softmax state + acc.
    """
    q_tile = block_q * d * itemsize
    kv = 2 * 2 * block_k * d * itemsize  # ×2 double-buffer
    state = block_q * (2 + d) * 4        # m, l, acc in f32
    scores = block_q * block_k * 4
    return q_tile + kv + state + scores


def mxu_utilization_estimate(block_q, block_k, d):
    """Fraction of MXU (128×128 systolic) lanes busy for the two BMMs."""
    def eff(m, n, k):
        pad = lambda x: -(-x // 128) * 128
        return (m * n * k) / (pad(m) * pad(n) * pad(k))
    return 0.5 * (eff(block_q, block_k, d) + eff(block_q, d, block_k))
