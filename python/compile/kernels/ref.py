"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has a reference here with identical
signature semantics; pytest + hypothesis assert allclose across
shapes/dtypes. These are also the "roofline" comparators for the
interpret-mode perf notes in EXPERIMENTS.md §Perf.
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=False, scale=None):
    """Multi-head scaled-dot-product attention.

    q, k, v: (B, H, S, D). Returns (B, H, S, D), computed in f32.
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        S = q.shape[2]
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def matmul_ref(a, b, *, activation=None):
    """C = act(A @ B). a: (M, K), b: (K, N), f32 accumulate."""
    c = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    if activation == "gelu":
        c = jax.nn.gelu(c, approximate=True)
    elif activation == "silu":
        c = jax.nn.silu(c)
    elif activation is not None:
        raise ValueError(f"unknown activation {activation!r}")
    return c


def rmsnorm_ref(x, w, *, eps=1e-6):
    """RMSNorm over the last dim. x: (..., H), w: (H,)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)


def softmax_ref(x):
    """Numerically-stable softmax over the last dim (f32)."""
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1)
