"""L1 Pallas kernels: tiled matmul (with fused epilogue) and RMSNorm.

The matmul is the MLP hot-spot of every model here (GPT/LLAMA FFN, MoE
experts). TPU-shaped: a (block_m, block_n) output tile lives in VMEM across
the K-grid dimension; each K step streams one (block_m, block_k) A tile and
one (block_k, block_n) B tile from HBM, feeding the MXU; the epilogue
(bias/activation) is fused into the final K step so the tile is written back
exactly once. ``interpret=True`` everywhere (CPU PJRT cannot run Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x):
    return jax.nn.gelu(x, approximate=True)


_ACTIVATIONS = {None: lambda x: x, "gelu": _gelu, "silu": jax.nn.silu}


def _matmul_kernel(a_ref, b_ref, o_ref, *, nk, activation):
    """Grid (M/bm, N/bn, K/bk); o_ref accumulates in f32 across the K axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] = _ACTIVATIONS[activation](o_ref[...])


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def matmul(a, b, *, activation=None, block_m=None, block_n=None, block_k=None):
    """C = act(A @ B). a: (M, K), b: (K, N) → (M, N) f32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    block_m = block_m or _largest_divisor(m, 128)
    block_n = block_n or _largest_divisor(n, 128)
    block_k = block_k or _largest_divisor(k, 128)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError("block shapes must divide (M, N, K)")

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, w, *, eps=1e-6, block_rows=None):
    """RMSNorm over the last dim. x: (R, H), w: (H,) → (R, H) f32.

    Row-blocked: each grid step normalizes ``block_rows`` rows with the whole
    H extent resident in VMEM (H·itemsize must fit — true for every model
    here; a production TPU kernel would two-pass larger H).
    """
    r, h = x.shape
    block_rows = block_rows or _largest_divisor(r, 128)
    if r % block_rows:
        raise ValueError("block_rows must divide R")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, h), jnp.float32),
        interpret=True,
    )(x, w)


def _largest_divisor(n, cap):
    for c in range(min(n, cap), 0, -1):
        if n % c == 0:
            return c
    return 1


def matmul_vmem_bytes(block_m, block_n, block_k, itemsize=4):
    """VMEM estimate: A+B tiles double-buffered + resident f32 output tile."""
    return 2 * (block_m * block_k + block_k * block_n) * itemsize + block_m * block_n * 4
