"""L1 Pallas kernels (interpret=True) + pure-jnp oracles.

Exports: attention (fused streaming-softmax MHA), matmul (tiled, fused
epilogue), rmsnorm, and the *_ref oracles used by pytest.
"""

from .attention import attention
from .mlp import matmul, rmsnorm
from .ref import attention_ref, matmul_ref, rmsnorm_ref, softmax_ref

__all__ = [
    "attention",
    "matmul",
    "rmsnorm",
    "attention_ref",
    "matmul_ref",
    "rmsnorm_ref",
    "softmax_ref",
]
