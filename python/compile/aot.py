"""AOT compile path: lower L2/L1 jax programs to HLO *text* artifacts.

Run once by ``make artifacts`` (no-op when fresh); the rust runtime loads
the text via ``HloModuleProto::from_text_file`` (see rust/src/runtime/).

HLO text — NOT ``lowered.compile()`` / proto ``.serialize()`` — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids that xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact families (all listed in artifacts/manifest.json):
  calib_*      — primitive compute programs (matmul / attention / rmsnorm at
                 swept shapes). The rust profiler executes these to build the
                 measured per-shape compute cost table that feeds T_P.
  layer_*      — one-block forward shards (full / DP / TP slices) used to
                 validate that composed primitive costs match a real fused
                 program.
  train_step_* — the full model train step for the e2e example (loss + SGD).
  quickstart   — a tiny one-block forward for examples/quickstart.rs.
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import attention as pallas_attention
from .kernels import matmul as pallas_matmul
from .kernels import rmsnorm as pallas_rmsnorm


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _aval_entry(name, aval):
    return {"name": name, "shape": list(aval.shape), "dtype": str(aval.dtype)}


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, specs, *, kind, input_names=None, meta=None):
        """Lower fn(*specs) and write <name>.hlo.txt + a manifest entry."""
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        flat, _ = jax.tree_util.tree_flatten(specs)
        if input_names is None:
            input_names = [f"arg{i}" for i in range(len(flat))]
        out_flat, _ = jax.tree_util.tree_flatten(
            jax.eval_shape(fn, *specs)
        )
        self.manifest.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": kind,
                "inputs": [_aval_entry(n, a) for n, a in zip(input_names, flat)],
                "outputs": [_aval_entry(f"out{i}", a) for i, a in enumerate(out_flat)],
                "meta": meta or {},
            }
        )
        print(f"  wrote {path} ({len(text)} chars, {len(flat)} inputs)")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote {path} ({len(self.manifest)} artifacts)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# --------------------------------------------------------------------------
# Calibration programs (primitive compute cost table)
# --------------------------------------------------------------------------

# (M, K, N) sweep covering the shard shapes the profiler will ask about:
# ~1e5 .. ~7e8 flops. Kept modest so `make artifacts` stays < ~2 min.
MATMUL_SHAPES = [
    (64, 64, 64),
    (128, 128, 128),
    (256, 256, 256),
    (512, 256, 256),
    (512, 512, 512),
    (512, 512, 1536),
    (512, 1024, 256),
    (1024, 512, 512),
    (1024, 1024, 1024),
    (2048, 512, 512),
    (2048, 1024, 512),
    (512, 256, 4096),
]

ATTN_SHAPES = [  # (B, H, S, D)
    (2, 4, 64, 32),
    (4, 8, 64, 32),
    (8, 8, 64, 32),
    (4, 8, 128, 32),
    (8, 8, 128, 64),
]

RMSNORM_SHAPES = [(512, 256), (2048, 512), (4096, 1024)]


def emit_calibration(em: Emitter):
    for m, k, n in MATMUL_SHAPES:
        em.emit(
            f"calib_matmul_{m}x{k}x{n}",
            lambda a, b: (jnp.matmul(a, b),),
            (f32(m, k), f32(k, n)),
            kind="calib_matmul",
            input_names=["a", "b"],
            meta={"m": m, "k": k, "n": n, "flops": 2 * m * k * n},
        )
    for b, h, s, d in ATTN_SHAPES:
        em.emit(
            f"calib_attn_{b}x{h}x{s}x{d}",
            lambda q, k, v: (pallas_attention(q, k, v, causal=True),),
            (f32(b, h, s, d),) * 3,
            kind="calib_attn",
            input_names=["q", "k", "v"],
            meta={"b": b, "h": h, "s": s, "d": d, "flops": 4 * b * h * s * s * d},
        )
    for r, hdim in RMSNORM_SHAPES:
        em.emit(
            f"calib_rmsnorm_{r}x{hdim}",
            lambda x, w: (pallas_rmsnorm(x, w),),
            (f32(r, hdim), f32(hdim)),
            kind="calib_rmsnorm",
            input_names=["x", "w"],
            meta={"rows": r, "hidden": hdim, "bytes": 4 * r * hdim},
        )


# --------------------------------------------------------------------------
# Layer shard programs (full / DP / TP) for composition validation
# --------------------------------------------------------------------------

def _layer_specs(cfg, batch):
    layer = {
        "ln1_w": f32(cfg.hidden),
        "ln1_b": f32(cfg.hidden),
        "wqkv": f32(cfg.hidden, 3 * cfg.hidden),
        "wo": f32(cfg.hidden, cfg.hidden),
        "ln2_w": f32(cfg.hidden),
        "ln2_b": f32(cfg.hidden),
        "w1": f32(cfg.hidden, cfg.ffn),
        "w2": f32(cfg.ffn, cfg.hidden),
    }
    if cfg.arch == "llama":
        layer = {
            "ln1_w": f32(cfg.hidden),
            "wqkv": f32(cfg.hidden, 3 * cfg.hidden),
            "wo": f32(cfg.hidden, cfg.hidden),
            "ln2_w": f32(cfg.hidden),
            "w_gate": f32(cfg.hidden, cfg.ffn),
            "w_up": f32(cfg.hidden, cfg.ffn),
            "w_down": f32(cfg.ffn, cfg.hidden),
        }
    return f32(batch, cfg.seq, cfg.hidden), layer


def tp_shard_forward(x, w, cfg, tp):
    """The per-device compute of a Megatron-TP transformer block shard.

    wqkv: (H, 3H/tp) column shard; wo: (H/tp, H) row shard (partial output —
    the AllReduce lives in the simulator, not here); MLP weights are
    column/row shards (GeLU MLP for gpt, SwiGLU for llama). heads/tp
    attention heads run locally.
    """
    b, s, h = x.shape
    heads = cfg.heads // tp
    hd = cfg.head_dim
    hx = x.reshape(b * s, h)
    qkv = M.pmatmul(hx, w["wqkv"]).reshape(b, s, 3, heads, hd)
    q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))
    o = M.pattention(q, k, v, True, None)
    o = o.transpose(0, 2, 1, 3).reshape(b * s, heads * hd)
    attn_partial = M.pmatmul(o, w["wo"])                       # partial sum
    if cfg.arch == "llama":
        gate = M.pmatmul(hx, w["w_gate"], "silu")
        up = M.pmatmul(hx, w["w_up"])
        mlp_partial = M.pmatmul(gate * up, w["w_down"])        # partial sum
    else:
        y = M.pmatmul(hx, w["w1"], "gelu")
        mlp_partial = M.pmatmul(y, w["w2"])                    # partial sum
    return (attn_partial + mlp_partial).reshape(b, s, h)


def emit_layers(em: Emitter, batch):
    for arch in ("gpt", "llama"):
        cfg = M.ModelConfig(arch=arch, hidden=256, layers=1, heads=8, ffn=1024, seq=64)
        for tag, bsz in (("full", batch), ("dp2", batch // 2), ("dp4", batch // 4)):
            x_spec, layer_spec = _layer_specs(cfg, bsz)
            names = ["x"] + [f"layer.{k}" for k in layer_spec]
            em.emit(
                f"layer_{arch}_{tag}",
                functools.partial(
                    lambda x, layer, cfg=cfg: (M.layer_forward(x, layer, cfg),)
                ),
                (x_spec, layer_spec),
                kind="layer",
                input_names=names,
                meta={"arch": arch, "batch": bsz, "shard": tag, "hidden": cfg.hidden},
            )
        for tp in (2, 4):
            heads = cfg.heads // tp
            w_spec = {
                "wqkv": f32(cfg.hidden, 3 * cfg.hidden // tp),
                "wo": f32(cfg.hidden // tp, cfg.hidden),
            }
            if arch == "llama":
                w_spec["w_gate"] = f32(cfg.hidden, cfg.ffn // tp)
                w_spec["w_up"] = f32(cfg.hidden, cfg.ffn // tp)
                w_spec["w_down"] = f32(cfg.ffn // tp, cfg.hidden)
            else:
                w_spec["w1"] = f32(cfg.hidden, cfg.ffn // tp)
                w_spec["w2"] = f32(cfg.ffn // tp, cfg.hidden)
            x_spec = f32(batch, cfg.seq, cfg.hidden)
            em.emit(
                f"layer_{arch}_tp{tp}",
                functools.partial(
                    lambda x, w, cfg=cfg, tp=tp: (tp_shard_forward(x, w, cfg, tp),)
                ),
                (x_spec, w_spec),
                kind="layer",
                input_names=["x"] + [f"w.{k}" for k in w_spec],
                meta={"arch": arch, "batch": batch, "shard": f"tp{tp}", "heads": heads},
            )


# --------------------------------------------------------------------------
# Train step (e2e) + quickstart
# --------------------------------------------------------------------------

def emit_train_step(em: Emitter, cfg: M.ModelConfig, batch, name):
    params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    tok_spec = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path((params, tok_spec, lr_spec))[0]
    names = ["/".join(str(k) for k in path) for path, _ in leaves_with_paths]

    step = functools.partial(
        lambda p, t, lr, cfg=cfg: M.train_step(p, t, lr, cfg)
    )
    em.emit(
        name,
        step,
        (params, tok_spec, lr_spec),
        kind="train_step",
        input_names=names,
        meta={
            "arch": cfg.arch,
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "ffn": cfg.ffn,
            "seq": cfg.seq,
            "batch": batch,
            "num_params": sum(
                int(functools.reduce(lambda a, b: a * b, l.shape, 1))
                for _, l in leaves_with_paths[:-2]
            ),
        },
    )


def emit_quickstart(em: Emitter):
    cfg = M.ModelConfig(arch="gpt", hidden=64, layers=1, heads=4, ffn=128, seq=16)
    x_spec, layer_spec = _layer_specs(cfg, 2)
    em.emit(
        "quickstart",
        functools.partial(lambda x, layer, cfg=cfg: (M.layer_forward(x, layer, cfg),)),
        (x_spec, layer_spec),
        kind="quickstart",
        input_names=["x"] + [f"layer.{k}" for k in layer_spec],
        meta={"arch": "gpt", "batch": 2, "hidden": 64, "seq": 16},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--e2e-hidden", type=int, default=int(os.environ.get("CFP_E2E_HIDDEN", 256)))
    ap.add_argument("--e2e-layers", type=int, default=int(os.environ.get("CFP_E2E_LAYERS", 4)))
    ap.add_argument("--e2e-batch", type=int, default=int(os.environ.get("CFP_E2E_BATCH", 8)))
    ap.add_argument("--only", default=None, help="comma list: calib,layers,train,quickstart")
    args = ap.parse_args()

    em = Emitter(args.out)
    sel = set(args.only.split(",")) if args.only else {"calib", "layers", "train", "quickstart"}
    if "calib" in sel:
        print("== calibration programs ==")
        emit_calibration(em)
    if "layers" in sel:
        print("== layer shard programs ==")
        emit_layers(em, args.batch)
    if "train" in sel:
        print("== train step (e2e) ==")
        cfg = M.ModelConfig(
            arch="gpt",
            vocab=4096,
            hidden=args.e2e_hidden,
            layers=args.e2e_layers,
            heads=8,
            ffn=4 * args.e2e_hidden,
            seq=64,
        )
        emit_train_step(em, cfg, args.e2e_batch, "train_step_gpt")
    if "quickstart" in sel:
        print("== quickstart ==")
        emit_quickstart(em)
    em.finish()


if __name__ == "__main__":
    sys.exit(main())
