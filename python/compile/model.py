"""L2: JAX model definitions (fwd/bwd) calling the L1 Pallas kernels.

Architectures mirror the paper's evaluation set structurally:
  * GPT   — pre-LN transformer, learned positions, GeLU MLP
  * LLAMA — RMSNorm (Pallas), RoPE, SwiGLU MLP
  * MoE   — GShard-style top-1 gated experts alternating with dense blocks

The Pallas kernels are wrapped in ``jax.custom_vjp`` so the *forward* hot
path is the L1 kernel while the backward pass is analytic (the backward
matmuls route through the Pallas matmul too). Everything lowers through
``jax.jit(...).lower`` in aot.py into one HLO module per artifact — Python
never runs at training/serving time.
"""

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import attention as _attention_fwd
from .kernels import matmul as _matmul_fwd
from .kernels import rmsnorm as _rmsnorm_fwd
from .kernels.ref import attention_ref  # noqa: F401  (oracle re-export for tests)


# --------------------------------------------------------------------------
# Differentiable wrappers around the Pallas kernels
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def pmatmul(a, b, activation=None):
    """act(A @ B) with the Pallas tiled-matmul forward."""
    return _matmul_fwd(a, b, activation=activation)


def _pmatmul_fwd(a, b, activation):
    pre = _matmul_fwd(a, b, activation=None)
    if activation is None:
        return pre, (a, b, None)
    return _apply_act(pre, activation), (a, b, pre)


def _apply_act(x, activation):
    if activation == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if activation == "silu":
        return jax.nn.silu(x)
    return x


def _act_grad(pre, activation):
    if activation is None:
        return jnp.ones_like(pre)
    return jax.vmap(jax.vmap(jax.grad(lambda t: _apply_act(t, activation))))(pre)


def _pmatmul_bwd(activation, res, g):
    a, b, pre = res
    if pre is not None:
        g = g * _act_grad(pre, activation)
    # Backward matmuls ride the same Pallas kernel.
    da = _matmul_fwd(g, b.T)
    db = _matmul_fwd(a.T, g)
    return da.astype(a.dtype), db.astype(b.dtype)


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def pattention(q, k, v, causal=False, scale=None):
    """Fused MHA with the Pallas streaming-softmax forward."""
    return _attention_fwd(q, k, v, causal=causal, scale=scale)


def _pattention_fwd(q, k, v, causal, scale):
    o = _attention_fwd(q, k, v, causal=causal, scale=scale)
    return o, (q, k, v)


def _pattention_bwd(causal, scale, res, do):
    q, k, v = res
    d = q.shape[-1]
    sc = scale if scale is not None else 1.0 / (d**0.5)
    qf, kf, vf, dof = (t.astype(jnp.float32) for t in (q, k, v, do))
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * sc
    if causal:
        S = q.shape[2]
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
    dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vf)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kf) * sc
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * sc
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


pattention.defvjp(_pattention_fwd, _pattention_bwd)


@jax.custom_vjp
def prmsnorm(x, w):
    return _rmsnorm_fwd(x, w)


def _prmsnorm_fwd(x, w):
    return _rmsnorm_fwd(x, w), (x, w)


def _prmsnorm_bwd(res, dy):
    x, w = res
    eps = 1e-6
    xf = x.astype(jnp.float32)
    h = x.shape[-1]
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    dyw = dy * w.astype(jnp.float32)
    dx = r * dyw - xf * (r**3 / h) * jnp.sum(dyw * xf, axis=-1, keepdims=True)
    dw = jnp.sum(dy * xf * r, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dw.astype(w.dtype)


prmsnorm.defvjp(_prmsnorm_fwd, _prmsnorm_bwd)


# --------------------------------------------------------------------------
# Configs and parameter init
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    arch: str = "gpt"           # gpt | llama | moe
    vocab: int = 4096
    hidden: int = 256
    layers: int = 4
    heads: int = 8
    ffn: int = 1024
    seq: int = 64
    experts: int = 4            # moe only
    rope_base: float = 10000.0  # llama only
    meta: dict = field(default_factory=dict, compare=False)

    @property
    def head_dim(self):
        return self.hidden // self.heads


def num_params(params):
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def init_params(key, cfg: ModelConfig):
    """Gaussian(0, 0.02) init. Leaf order == manifest order == rust order."""
    std = 0.02
    keys = iter(jax.random.split(key, 16 + 16 * cfg.layers))

    def norm(*shape):
        return jax.random.normal(next(keys), shape, jnp.float32) * std

    params = {"embed": norm(cfg.vocab, cfg.hidden)}
    if cfg.arch != "llama":
        params["pos"] = norm(cfg.seq, cfg.hidden)
    layers = []
    for li in range(cfg.layers):
        layer = {
            "ln1_w": jnp.ones((cfg.hidden,), jnp.float32),
            "wqkv": norm(cfg.hidden, 3 * cfg.hidden),
            "wo": norm(cfg.hidden, cfg.hidden),
            "ln2_w": jnp.ones((cfg.hidden,), jnp.float32),
        }
        if cfg.arch != "llama":
            layer["ln1_b"] = jnp.zeros((cfg.hidden,), jnp.float32)
            layer["ln2_b"] = jnp.zeros((cfg.hidden,), jnp.float32)
        if cfg.arch == "llama":
            layer["w_gate"] = norm(cfg.hidden, cfg.ffn)
            layer["w_up"] = norm(cfg.hidden, cfg.ffn)
            layer["w_down"] = norm(cfg.ffn, cfg.hidden)
        elif cfg.arch == "moe" and li % 2 == 1:
            layer["gate"] = norm(cfg.hidden, cfg.experts)
            layer["w1_e"] = norm(cfg.experts, cfg.hidden, cfg.ffn)
            layer["w2_e"] = norm(cfg.experts, cfg.ffn, cfg.hidden)
        else:
            layer["w1"] = norm(cfg.hidden, cfg.ffn)
            layer["w2"] = norm(cfg.ffn, cfg.hidden)
        layers.append(layer)
    params["layers"] = layers
    params["lnf_w"] = jnp.ones((cfg.hidden,), jnp.float32)
    if cfg.arch != "llama":
        params["lnf_b"] = jnp.zeros((cfg.hidden,), jnp.float32)
    params["unembed"] = norm(cfg.hidden, cfg.vocab)
    return params


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (xf - mu) * jax.lax.rsqrt(var + eps) * w + b


def _rope(x, base):
    """Rotary embedding. x: (B, H, S, D)."""
    b, h, s, d = x.shape
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    t = jnp.arange(s, dtype=jnp.float32)
    ang = jnp.einsum("s,f->sf", t, freqs)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _mha(x, layer, cfg, *, rope=False):
    b, s, h = x.shape
    qkv = pmatmul(x.reshape(b * s, h), layer["wqkv"]).reshape(
        b, s, 3, cfg.heads, cfg.head_dim
    )
    q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))
    if rope:
        q, k = _rope(q, cfg.rope_base), _rope(k, cfg.rope_base)
    o = pattention(q, k, v, True, None)
    o = o.transpose(0, 2, 1, 3).reshape(b * s, h)
    return pmatmul(o, layer["wo"]).reshape(b, s, h)


def gpt_block(x, layer, cfg):
    b, s, h = x.shape
    hx = _layernorm(x, layer["ln1_w"], layer["ln1_b"])
    x = x + _mha(hx, layer, cfg)
    hx = _layernorm(x, layer["ln2_w"], layer["ln2_b"])
    y = pmatmul(hx.reshape(b * s, h), layer["w1"], "gelu")
    y = pmatmul(y, layer["w2"]).reshape(b, s, h)
    return x + y


def llama_block(x, layer, cfg):
    b, s, h = x.shape
    hx = prmsnorm(x.reshape(b * s, h), layer["ln1_w"]).reshape(b, s, h)
    x = x + _mha(hx, layer, cfg, rope=True)
    hx = prmsnorm(x.reshape(b * s, h), layer["ln2_w"])
    gate = pmatmul(hx, layer["w_gate"], "silu")
    up = pmatmul(hx, layer["w_up"])
    y = pmatmul(gate * up, layer["w_down"]).reshape(b, s, h)
    return x + y


def moe_ffn(x2d, layer, cfg):
    """GShard-style top-1 gating with softmax load weighting.

    x2d: (T, H). Dispatch/combine are one-hot contractions — exactly the
    BMM-over-experts structure whose partitioning the paper's MoE case
    study (§5.7) revolves around.
    """
    logits = pmatmul(x2d, layer["gate"])                         # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1)
    onehot = jax.nn.one_hot(idx, cfg.experts, dtype=x2d.dtype)   # (T, E)
    weight = jnp.sum(probs * onehot, axis=-1, keepdims=True)     # (T, 1)
    xe = jnp.einsum("te,th->eth", onehot, x2d)                   # dispatch
    h1 = jax.nn.gelu(jnp.einsum("eth,ehf->etf", xe, layer["w1_e"]), approximate=True)
    h2 = jnp.einsum("etf,efh->eth", h1, layer["w2_e"])
    y = jnp.einsum("te,eth->th", onehot, h2)                     # combine
    return y * weight


def moe_block(x, layer, cfg, li):
    b, s, h = x.shape
    hx = _layernorm(x, layer["ln1_w"], layer["ln1_b"])
    x = x + _mha(hx, layer, cfg)
    hx = _layernorm(x, layer["ln2_w"], layer["ln2_b"]).reshape(b * s, h)
    if li % 2 == 1:
        y = moe_ffn(hx, layer, cfg).reshape(b, s, h)
    else:
        y = pmatmul(hx, layer["w1"], "gelu")
        y = pmatmul(y, layer["w2"]).reshape(b, s, h)
    return x + y


_BLOCKS = {"gpt": gpt_block, "llama": llama_block}


def forward(params, tokens, cfg: ModelConfig):
    """tokens: (B, S) int32 → logits (B, S, V)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    if cfg.arch != "llama":
        x = x + params["pos"][None, :s]
    for li, layer in enumerate(params["layers"]):
        if cfg.arch == "moe":
            x = moe_block(x, layer, cfg, li)
        else:
            x = _BLOCKS[cfg.arch](x, layer, cfg)
    if cfg.arch == "llama":
        x = prmsnorm(x.reshape(b * s, cfg.hidden), params["lnf_w"])
    else:
        x = _layernorm(x, params["lnf_w"], params["lnf_b"]).reshape(b * s, cfg.hidden)
    logits = pmatmul(x, params["unembed"])
    return logits.reshape(b, s, cfg.vocab)


def loss_fn(params, tokens, cfg: ModelConfig):
    """Next-token cross-entropy over positions 0..S-2."""
    logits = forward(params, tokens, cfg)[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(params, tokens, lr, cfg: ModelConfig):
    """One SGD step. Returns (loss, new_params)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, cfg))(params)
    new_params = jax.tree_util.tree_map(
        lambda p, g: p - lr * g.astype(p.dtype), params, grads
    )
    return loss, new_params


def layer_forward(x, layer_params, cfg: ModelConfig, li=0):
    """Single-block forward — the unit the profiler executes per shard."""
    if cfg.arch == "moe":
        return moe_block(x, layer_params, cfg, li)
    return _BLOCKS[cfg.arch](x, layer_params, cfg)
