"""L1 tiled-matmul + rmsnorm kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, matmul_ref, rmsnorm, rmsnorm_ref

SETTINGS = dict(deadline=None, max_examples=25)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([8, 32, 96, 128]),
    k=st.sampled_from([16, 64, 128]),
    n=st.sampled_from([8, 48, 128]),
    act=st.sampled_from([None, "gelu", "silu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, act, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (m, k), jnp.float32)
    b = jax.random.normal(k2, (k, n), jnp.float32)
    out = matmul(a, b, activation=act)
    ref = matmul_ref(a, b, activation=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    bm=st.sampled_from([8, 16, 64]),
    bn=st.sampled_from([8, 32, 64]),
    bk=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_block_shape_invariance(bm, bn, bk, seed):
    """K-axis accumulation order must not change the result materially."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (64, 64), jnp.float32)
    b = jax.random.normal(k2, (64, 64), jnp.float32)
    out = matmul(a, b, block_m=bm, block_n=bn, block_k=bk)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5, rtol=5e-5)


def test_matmul_bf16():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (32, 64), jnp.float32).astype(jnp.bfloat16)
    b = jax.random.normal(k2, (64, 32), jnp.float32).astype(jnp.bfloat16)
    out = matmul(a, b)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-2, rtol=5e-2)


def test_matmul_identity():
    a = jnp.eye(32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    np.testing.assert_allclose(np.asarray(matmul(a, b)), np.asarray(b), atol=1e-6)


def test_matmul_rejects_bad_blocks():
    a, b = jnp.zeros((30, 30)), jnp.zeros((30, 30))
    with pytest.raises(ValueError):
        matmul(a, b, block_m=7)
    with pytest.raises(ValueError):
        matmul(a, b, activation="relu6")


@settings(**SETTINGS)
@given(
    r=st.sampled_from([8, 64, 128]),
    h=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(r, h, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (r, h), jnp.float32)
    w = jax.random.normal(k2, (h,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, w)), np.asarray(rmsnorm_ref(x, w)), atol=2e-5, rtol=2e-5
    )


def test_rmsnorm_unit_norm_rows():
    """Rows of equal magnitude with w=1 normalize to unit RMS."""
    x = jnp.full((4, 64), 3.0)
    w = jnp.ones((64,))
    out = np.asarray(rmsnorm(x, w))
    rms = np.sqrt((out**2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-4)
