"""L1 attention kernel vs pure-jnp oracle: hypothesis sweep + edge cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, attention_ref

SETTINGS = dict(deadline=None, max_examples=20)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 3]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([16, 32, 48, 64]),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, s, d, causal, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (rand(kk, (b, h, s, d), jnp.float32) for kk in ks)
    out = attention(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    block_q=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_block_shape_invariance(block_q, block_k, seed):
    """Output must not depend on the VMEM tiling schedule."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (rand(kk, (2, 2, 32, 16), jnp.float32) for kk in ks)
    out = attention(q, k, v, block_q=block_q, block_k=block_k)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_attention_causal_block_shape_invariance():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (rand(kk, (1, 2, 64, 16), jnp.float32) for kk in ks)
    ref = attention_ref(q, k, v, causal=True)
    for bq, bk in [(16, 16), (32, 16), (64, 32), (16, 8)]:
        out = attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_attention_bf16_inputs():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (rand(kk, (2, 2, 32, 16), jnp.bfloat16) for kk in ks)
    out = attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=3e-2, rtol=3e-2
    )


def test_attention_custom_scale():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (rand(kk, (1, 1, 16, 8), jnp.float32) for kk in ks)
    out = attention(q, k, v, scale=0.25)
    ref = attention_ref(q, k, v, scale=0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_attention_rejects_bad_blocks():
    q = jnp.zeros((1, 1, 32, 8))
    with pytest.raises(ValueError):
        attention(q, q, q, block_q=24)
    with pytest.raises(ValueError):
        attention(q, q, q, causal=True, block_q=8, block_k=16)


def test_attention_one_hot_rows():
    """Softmax over a row with one huge logit selects that V row."""
    s, d = 16, 8
    q = jnp.zeros((1, 1, s, d)).at[0, 0, :, 0].set(100.0)
    k = jnp.zeros((1, 1, s, d)).at[0, 0, 3, 0].set(100.0)
    v = jnp.arange(s * d, dtype=jnp.float32).reshape(1, 1, s, d)
    out = attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out[0, 0, 5]), np.asarray(v[0, 0, 3]), atol=1e-3
    )
