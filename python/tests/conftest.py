import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
