"""AOT path: HLO text artifacts are well-formed and manifest-consistent."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    em = aot.Emitter(out)
    aot.emit_quickstart(em)
    cfg = M.ModelConfig(arch="gpt", vocab=128, hidden=32, layers=1, heads=2, ffn=64, seq=16)
    aot.emit_train_step(em, cfg, batch=2, name="train_step_tiny")
    em.finish()
    return out, em.manifest


def test_hlo_text_is_parseable_shape(emitted):
    out, manifest = emitted
    for entry in manifest:
        text = open(os.path.join(out, entry["file"])).read()
        assert text.startswith("HloModule"), entry["name"]
        assert "ENTRY" in text
        # one parameter instruction per manifest input (ENTRY computation
        # only — nested while/fusion computations have their own parameters)
        entry_text = text[text.rindex("ENTRY") :]
        assert entry_text.count(" parameter(") == len(entry["inputs"]), entry["name"]


def test_manifest_records_io_avals(emitted):
    _, manifest = emitted
    ts = next(e for e in manifest if e["name"] == "train_step_tiny")
    # params... + tokens + lr
    assert ts["inputs"][-1]["shape"] == []          # lr scalar
    assert ts["inputs"][-2]["dtype"] == "int32"     # tokens
    # outputs: loss + one per param leaf
    assert len(ts["outputs"]) == len(ts["inputs"]) - 2 + 1
    assert ts["outputs"][0]["shape"] == []          # loss scalar


def test_train_step_meta_param_count(emitted):
    _, manifest = emitted
    ts = next(e for e in manifest if e["name"] == "train_step_tiny")
    cfg = M.ModelConfig(arch="gpt", vocab=128, hidden=32, layers=1, heads=2, ffn=64, seq=16)
    n = M.num_params(M.init_params(jax.random.PRNGKey(0), cfg))
    assert ts["meta"]["num_params"] == n


def test_manifest_json_round_trips(emitted):
    out, manifest = emitted
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert [e["name"] for e in loaded] == [e["name"] for e in manifest]


def test_tp_shard_partial_sums_compose():
    """full-layer output == sum-free check: DP shard at b/2 equals slicing
    the full output; TP shards sum to the full output (the AllReduce the
    simulator inserts)."""
    cfg = M.ModelConfig(arch="gpt", vocab=128, hidden=32, layers=1, heads=4, ffn=64, seq=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    layer = params["layers"][0]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.seq, cfg.hidden))

    tp = 2
    h = cfg.hidden
    hx = M._layernorm(x, layer["ln1_w"], layer["ln1_b"])
    # column-shard wqkv by heads: reshape (H, 3, heads, hd) and slice heads
    wqkv = layer["wqkv"].reshape(h, 3, cfg.heads, cfg.head_dim)
    shard_out = 0.0
    for r in range(tp):
        lo, hi = r * cfg.heads // tp, (r + 1) * cfg.heads // tp
        w = {
            "wqkv": wqkv[:, :, lo:hi].reshape(h, 3 * h // tp),
            "wo": layer["wo"][lo * cfg.head_dim : hi * cfg.head_dim],
            "w1": layer["w1"][:, r * cfg.ffn // tp : (r + 1) * cfg.ffn // tp],
            "w2": layer["w2"][r * cfg.ffn // tp : (r + 1) * cfg.ffn // tp],
        }
        shard_out = shard_out + aot.tp_shard_forward(hx, w, cfg, tp)

    # tp_shard_forward runs attn+mlp over the same (already-normed) input —
    # a profiling proxy for the two Megatron partial sums, not the exact
    # residual chain. Compare against the identical full composition.
    b, s, _ = x.shape
    full = M._mha(hx, layer, cfg)
    y1 = M.pmatmul(hx.reshape(b * s, h), layer["w1"], "gelu")
    y1 = M.pmatmul(y1, layer["w2"]).reshape(b, s, h)
    import numpy as np

    expect = np.asarray(full + y1)
    np.testing.assert_allclose(np.asarray(shard_out), expect, atol=1e-4, rtol=1e-4)
