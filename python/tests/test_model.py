"""L2 model: shapes, gradient correctness (finite differences through the
custom-vjp Pallas wrappers), and that training actually learns."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = dict(vocab=256, hidden=32, layers=2, heads=2, ffn=64, seq=16)


@pytest.fixture(params=["gpt", "llama", "moe"])
def arch(request):
    return request.param


def _setup(arch, seed=0):
    cfg = M.ModelConfig(arch=arch, experts=2, **TINY)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, cfg.seq), 0, cfg.vocab)
    return cfg, params, tokens


def test_forward_shapes(arch):
    cfg, params, tokens = _setup(arch)
    logits = M.forward(params, tokens, cfg)
    assert logits.shape == (2, cfg.seq, cfg.vocab)
    assert jnp.isfinite(logits).all()


def test_loss_finite_and_near_uniform_at_init(arch):
    cfg, params, tokens = _setup(arch)
    loss = M.loss_fn(params, tokens, cfg)
    # ~log(V) at random init
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_gradients_match_finite_differences(arch):
    """<grad, u> vs central finite difference along a random direction —
    validates every custom_vjp (pmatmul/pattention/prmsnorm) end to end.

    Skipped for moe: top-1 argmax gating makes the loss piecewise — FD
    across an expert-switch boundary measures the jump, not the gradient.
    The moe path is covered by test_gradients_match_pure_jnp_autodiff.
    """
    if arch == "moe":
        pytest.skip("argmax gating is piecewise; covered by the autodiff test")
    cfg, params, tokens = _setup(arch)
    loss, grads = jax.value_and_grad(lambda p: M.loss_fn(p, tokens, cfg))(params)
    u = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(hash(p.shape) % 2**31), p.shape),
        params,
    )
    eps = 1e-3
    plus = jax.tree_util.tree_map(lambda p, d: p + eps * d, params, u)
    minus = jax.tree_util.tree_map(lambda p, d: p - eps * d, params, u)
    fd = (M.loss_fn(plus, tokens, cfg) - M.loss_fn(minus, tokens, cfg)) / (2 * eps)
    dot = sum(
        jnp.vdot(g, d)
        for g, d in zip(jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(u))
    )
    np.testing.assert_allclose(float(fd), float(dot), rtol=5e-2, atol=5e-3)


def test_gradients_match_pure_jnp_autodiff(arch, monkeypatch):
    """jax.grad through the Pallas custom-vjp wrappers must equal jax.grad
    through the pure-jnp reference ops (default autodiff, no custom vjp)."""
    from compile.kernels import ref as R

    cfg, params, tokens = _setup(arch)
    grads_pallas = jax.grad(lambda p: M.loss_fn(p, tokens, cfg))(params)

    monkeypatch.setattr(
        M, "pmatmul", lambda a, b, activation=None: R.matmul_ref(a, b, activation=activation)
    )
    monkeypatch.setattr(
        M,
        "pattention",
        lambda q, k, v, causal=False, scale=None: R.attention_ref(
            q, k, v, causal=causal, scale=scale
        ),
    )
    monkeypatch.setattr(M, "prmsnorm", lambda x, w: R.rmsnorm_ref(x, w))
    grads_ref = jax.grad(lambda p: M.loss_fn(p, tokens, cfg))(params)

    for gp, gr in zip(
        jax.tree_util.tree_leaves(grads_pallas), jax.tree_util.tree_leaves(grads_ref)
    ):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), atol=2e-4, rtol=2e-3)


def test_train_reduces_loss(arch):
    cfg, params, tokens = _setup(arch)
    step = jax.jit(lambda p, t: M.train_step(p, t, 0.5, cfg))
    first, params = step(params, tokens)
    loss = first
    for _ in range(5):
        loss, params = step(params, tokens)
    assert float(loss) < float(first) - 0.1, (float(first), float(loss))


def test_train_step_is_pure(arch):
    cfg, params, tokens = _setup(arch)
    l1, _ = M.train_step(params, tokens, 0.1, cfg)
    l2, _ = M.train_step(params, tokens, 0.1, cfg)
    assert float(l1) == float(l2)


def test_moe_expert_dispatch_partitions_tokens():
    """Each token goes to exactly one expert and the outputs recombine."""
    cfg = M.ModelConfig(arch="moe", experts=4, **TINY)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    layer = params["layers"][1]
    x = jax.random.normal(jax.random.PRNGKey(2), (8, cfg.hidden))
    y = M.moe_ffn(x, layer, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()


def test_param_counts_scale_with_layers():
    cfg2 = M.ModelConfig(arch="gpt", **{**TINY, "layers": 2})
    cfg4 = M.ModelConfig(arch="gpt", **{**TINY, "layers": 4})
    n2 = M.num_params(M.init_params(jax.random.PRNGKey(0), cfg2))
    n4 = M.num_params(M.init_params(jax.random.PRNGKey(0), cfg4))
    assert n4 > n2
    per_layer = (n4 - n2) / 2
    # 4 attn mats (4h^2) + 2 mlp mats (2*h*ffn) dominate
    expected = 4 * cfg2.hidden**2 + 2 * cfg2.hidden * cfg2.ffn
    assert abs(per_layer - expected) / expected < 0.1
